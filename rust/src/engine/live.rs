//! Live engine: TinyLM decode with the wave index + wave buffer between
//! qkv and attention (paper Figure 5), executed through PJRT. Also
//! provides a full-attention mode over the same sessions for accuracy
//! and latency comparison.

use super::assemble::{assemble_head, AssembleShape, BatchAssembler, HeadSlices, HeadTask};
use crate::buffer::{ExecBuffer, SharedBlockCache, WaveBuffer};
use crate::config::{BufferConfig, CapacityConfig, SpillCodec, ZoneConfig};
use crate::coordinator::AdmissionConfig;
use crate::index::{BuildScratch, SelectScratch, SnapshotError, WaveIndex};
use crate::kvcache::prefix::{ChainGeometry, PrefixMatch, PrefixRegistry};
use crate::kvcache::{AllocError, BlockArena, CodecTag, SpillPolicy, TenantId, DEFAULT_TENANT};
use crate::metrics::Metrics;
use crate::runtime::tinylm::{TinyLm, WaveInputs};
use crate::tensor::Tensor;
use crate::util::threadpool::ThreadPool;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Attention mode for decode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttnMode {
    /// Wave index + tripartite kernel (RetroInfer).
    Wave,
    /// Dense attention over the padded cache (baseline).
    Full,
}

/// Reused decode-step buffers: the kernel input tensor plus the
/// per-step token/position/query staging vectors. Taken out of the
/// engine at step start and restored at the end, so steady-state decode
/// (same batch width) allocates nothing on the engine side. An error
/// mid-step drops the scratch (the next step reallocates) — correctness
/// never depends on the reuse.
#[derive(Default)]
struct StepScratch {
    /// Cached wave-kernel inputs, valid when the batch width matches.
    wi: Option<WaveInputs>,
    /// Batch width `wi` was sized for.
    wi_rows: usize,
    /// `[b*kvh, G, d]` flat group queries (rebuilt every layer).
    qg_all: Vec<f32>,
    tokens: Vec<i32>,
    pos: Vec<i32>,
    /// Segment-clustering gather buffers shared across prefill chunks
    /// (and across every head of every chunk): a warm chunk that stays
    /// inside a build segment allocates nothing engine-side.
    build: BuildScratch,
}

/// Per-request live state.
struct SessionState {
    /// Wave indexes, `[layer * kv_heads]` (Wave mode).
    indexes: Vec<WaveIndex>,
    buffers: Vec<WaveBuffer>,
    /// Full-attention caches per layer: `[KVH, T, d]` flat (Full mode).
    k_full: Vec<Vec<f32>>,
    v_full: Vec<Vec<f32>>,
    len: usize,
    last_token: i32,
}

/// The live serving engine.
pub struct LiveEngine {
    lm: TinyLm,
    zcfg: ZoneConfig,
    bcfg: BufferConfig,
    mode: AttnMode,
    pool: Arc<ThreadPool>,
    /// Engine-owned KV block pool shared by every session and head.
    arena: Arc<BlockArena>,
    assembler: BatchAssembler,
    states: HashMap<u64, SessionState>,
    /// Cold-tier spill: `Some(policy)` arms demote-then-retry on every
    /// layer (index appends, prefill builds, promotions) plus the
    /// decode-step prefetch worker. `None` = single-tier (PR 2
    /// semantics exactly).
    spill_policy: Option<Arc<dyn SpillPolicy>>,
    /// Cross-session prefix registry (DESIGN.md §2 "Prefix sharing &
    /// CoW"): `Some` arms longest-prefix matching + sealing in
    /// `prefill_for`. `None` = every session materializes its own
    /// prefix (pre-sharing semantics exactly).
    prefix: Option<Arc<PrefixRegistry>>,
    /// Derive clustering seeds from prompt content instead of session
    /// id (required for prefix sharing: two sessions with the same
    /// prefix must cluster it identically; also settable alone to get a
    /// sharing-comparable unshared baseline).
    content_seeds: bool,
    /// Cross-session shared GPU block caches, one per (layer, kv-head)
    /// slot (created lazily when prefix sharing is armed).
    shared_caches: Vec<Arc<SharedBlockCache>>,
    /// Engine-level byte budget for the shared caches, split evenly
    /// across all (layer, kv-head) slots. `None` = size each slot from
    /// the engine's max context bucket (the pre-budget sizing).
    shared_cache_budget: Option<usize>,
    /// Cold-tier spill codec (DESIGN.md §2 "Spill codecs"): applied by
    /// the spill store to lossy-eligible pages only. `Exact` keeps
    /// tiered serving bit-identical.
    spill_codec: SpillCodec,
    /// Accuracy bound handed to every session index (mean member-key
    /// cosine a cluster must clear before its pages may go lossy).
    lossy_cos_floor: f32,
    pub metrics: Arc<Metrics>,
    scratch: SelectScratch,
    step: StepScratch,
    /// Sessions preempted to the cold tier mid-generation
    /// ([`LiveEngine::preempt_session`]): the full bit-exact snapshot
    /// parked off the arena, resumable any time via
    /// [`LiveEngine::resume_session`].
    parked: HashMap<u64, SessionSnapshot>,
}

/// A resumable chunked prefill (DESIGN.md §2 "Online serving &
/// preemption"). [`LiveEngine::prefill_start`] runs the LM forward once
/// — TinyLM's prefill is a whole-prompt AOT executable, so chunking
/// applies to the index build, not the forward — and opens every
/// per-(layer, kv-head) wave index as a chunked build over the cached
/// KV. Each [`LiveEngine::prefill_advance`] feeds `chunk_tokens` more
/// rows through the same segmented re-cluster path a monolithic build
/// takes, so the scheduler can interleave prefill chunks with decode
/// steps; [`LiveEngine::prefill_finish`] registers the session. The
/// finished session is bit-identical to [`LiveEngine::prefill_for`]'s,
/// which now runs through this job as one maximal chunk. Dropping a job
/// aborts the build and returns every checked-out block to the arena.
pub struct PrefillJob {
    id: u64,
    tenant: TenantId,
    prompt: Vec<i32>,
    /// Cached prefill KV, `[L, 1, KVH, T, d]`.
    kc: Tensor,
    vc: Tensor,
    /// First generated token (from the prefill logits).
    first: i32,
    /// Open chunked builds, `[layer * kv_heads]`.
    indexes: Vec<WaveIndex>,
    k_full: Vec<Vec<f32>>,
    v_full: Vec<Vec<f32>>,
    /// Tokens covered by the grafted prefix match, if any.
    matched_covered: Option<usize>,
    /// Prompt rows fed to every slot so far.
    fed: usize,
    /// Total prompt tokens.
    t: usize,
    /// Wall time spent in start/advance so far (folded into the
    /// `prefill_s` observation at finish, so chunked and monolithic
    /// prefills report comparably).
    spent_s: f64,
}

impl PrefillJob {
    pub fn id(&self) -> u64 {
        self.id
    }
    pub fn tenant(&self) -> TenantId {
        self.tenant
    }
    /// Total prompt tokens this job must feed.
    pub fn total_tokens(&self) -> usize {
        self.t
    }
    /// Prompt tokens fed so far.
    pub fn fed_tokens(&self) -> usize {
        self.fed
    }
    /// Whether every prompt token has been fed (ready for
    /// [`LiveEngine::prefill_finish`]).
    pub fn done(&self) -> bool {
        self.fed == self.t
    }
}

impl LiveEngine {
    pub fn new(artifacts_dir: &str, mode: AttnMode) -> Result<LiveEngine> {
        // Live-path zone config, calibrated for TinyLM at 2-8K contexts:
        // the paper's 1.8%/23.2% budgets are calibrated for trained LLMs
        // at 128K, whose key space is far more cluster-coherent than a
        // synthetic-weight 4-layer model at 2K. DESIGN.md §1 documents the
        // substitution; the paper-scale fractions stay the default for
        // memsim/benches. The smaller update segment keeps the steady
        // zone inside the execution buffer (Ne) with retrieval room.
        let zcfg = ZoneConfig {
            retrieval_frac: 0.5,
            estimation_frac: 1.0, // estimate every non-retrieved cluster
            build_segment: 2048,
            update_segment: 256,
            ..ZoneConfig::default()
        };
        // Live cache sizing: with TinyLM's 50% retrieval budget the
        // working set is ~10x the paper's (1.8%); scale the GPU cache
        // the same way (25% of KV) so the locality story is preserved.
        let bcfg = BufferConfig { cache_frac: 0.25, ..BufferConfig::default() };
        Self::with_config(artifacts_dir, mode, zcfg, bcfg)
    }

    pub fn with_config(
        artifacts_dir: &str,
        mode: AttnMode,
        zcfg: ZoneConfig,
        bcfg: BufferConfig,
    ) -> Result<LiveEngine> {
        let lm = TinyLm::load(artifacts_dir)?;
        let pool = Arc::new(ThreadPool::new(bcfg.cpu_threads.max(1)));
        let arena = BlockArena::shared(lm.cfg.d_head, bcfg.block_bytes);
        let assembler = BatchAssembler::new(Arc::clone(&pool), bcfg.cpu_threads > 1);
        // Pin the kernel backend now (logs once per process) and expose
        // which one decode will run on as a gauge.
        let backend = crate::kernels::active();
        let metrics = Arc::new(Metrics::new());
        metrics.set_gauge(
            "kernel_simd",
            u64::from(!matches!(backend, crate::kernels::Backend::Scalar)),
        );
        Ok(LiveEngine {
            lm,
            zcfg,
            bcfg,
            mode,
            pool,
            arena,
            assembler,
            states: HashMap::new(),
            spill_policy: None,
            prefix: None,
            content_seeds: false,
            shared_caches: Vec::new(),
            shared_cache_budget: None,
            spill_codec: SpillCodec::Exact,
            lossy_cos_floor: 1.0,
            metrics,
            scratch: SelectScratch::default(),
            step: StepScratch::default(),
            parked: HashMap::new(),
        })
    }

    /// The engine-wide KV block arena (occupancy / reclaim accounting).
    pub fn arena(&self) -> &Arc<BlockArena> {
        &self.arena
    }

    /// Enable cold-tier spill under `policy`: from here on a full hot
    /// tier means "demote, then retry" (prefill builds, decode appends,
    /// promotions) instead of a hard refusal, and decode steps prefetch
    /// the clusters the estimator selected for the *next* step through
    /// the thread-pool so promotion overlaps compute. Applies to
    /// already-live sessions too.
    pub fn enable_spill(&mut self, policy: Arc<dyn SpillPolicy>) {
        for st in self.states.values_mut() {
            for idx in st.indexes.iter_mut() {
                idx.set_spill_policy(Some(Arc::clone(&policy)));
            }
        }
        self.spill_policy = Some(policy);
        // Stage-decoupled decode pipeline on by default under spill:
        // cold-page reads are issued on the pool's I/O lane the moment
        // selection completes and gathers drain in completion order, so
        // spill latency hides under attention compute within the step.
        self.assembler.set_pipelined(true);
    }

    /// Arm/disarm the stage-decoupled (select → async I/O → gather)
    /// decode pipeline explicitly. Enabled by default by
    /// [`LiveEngine::enable_spill`]; bit-identical to the sequential
    /// path either way (property-tested in `tests/spill.rs`).
    pub fn set_pipelined_decode(&mut self, on: bool) {
        self.assembler.set_pipelined(on);
    }

    /// Whether the pipelined decode executor is armed.
    pub fn pipelined_decode(&self) -> bool {
        self.assembler.pipelined()
    }

    /// Bound the spill staging area to `depth` pages — the pipeline's
    /// prefetch-depth knob (`None` = unbounded). Oldest staged pages
    /// are evicted first; eviction only costs a wasted prefetch, never
    /// correctness (evicted pages fall back to the synchronous read).
    pub fn set_pipeline_depth(&self, depth: Option<usize>) {
        self.arena.set_staging_cap(depth);
    }

    /// Whether cold-tier spill is armed.
    pub fn spill_enabled(&self) -> bool {
        self.spill_policy.is_some()
    }

    /// Select the cold-tier spill codec and the accuracy bound for
    /// lossy placement. The codec compresses only pages the wave
    /// index's estimation head cleared (`lossy_ok`); everything else —
    /// and everything when `codec` is `Exact` — round-trips
    /// bit-identically. Applies to already-live sessions and to every
    /// session built afterwards; pages already cold keep the codec they
    /// were written with.
    pub fn set_spill_codec(&mut self, codec: SpillCodec, lossy_cos_floor: f32) {
        self.spill_codec = codec;
        // a lossless codec forbids lossy placement outright (floor 1.0),
        // so exact-codec runs never pay the eligibility scan at demote
        self.lossy_cos_floor = if codec.is_lossy() { lossy_cos_floor } else { 1.0 };
        let tag = match codec {
            SpillCodec::Exact => CodecTag::Exact,
            SpillCodec::Int8 => CodecTag::Int8Angle,
            SpillCodec::Int4 => CodecTag::Int4Angle,
            SpillCodec::LowRankK => CodecTag::LowRankK,
        };
        self.arena.spill().set_codec(tag);
        for st in self.states.values_mut() {
            for idx in st.indexes.iter_mut() {
                idx.set_lossy_cos_floor(self.lossy_cos_floor);
            }
        }
    }

    /// The configured cold-tier spill codec.
    pub fn spill_codec(&self) -> SpillCodec {
        self.spill_codec
    }

    /// Arm cross-session prefix sharing: prefills match the longest
    /// registered token-hash chain and check sealed blocks out as
    /// shared, refcounted views instead of recomputing/re-clustering
    /// them; unmatched prefills seal and register their own prefix.
    /// Implies content-derived clustering seeds (sharing requires the
    /// same tokens to cluster the same way in every session). Returns
    /// the registry so the scheduler can discount admission footprints
    /// (`Scheduler::set_prefix_registry`).
    pub fn enable_prefix_sharing(&mut self, max_entries: usize) -> Arc<PrefixRegistry> {
        self.content_seeds = true;
        let reg =
            PrefixRegistry::shared(Arc::clone(&self.arena), self.chain_geometry(), max_entries);
        self.prefix = Some(Arc::clone(&reg));
        reg
    }

    /// The armed prefix registry, if any.
    pub fn prefix_registry(&self) -> Option<&Arc<PrefixRegistry>> {
        self.prefix.as_ref()
    }

    /// Derive clustering seeds from prompt content instead of session
    /// id. On its own (registry unarmed) this produces the unshared
    /// baseline whose tokens are bit-comparable to a sharing-enabled
    /// run of the same prompts.
    pub fn set_content_seeds(&mut self, on: bool) {
        self.content_seeds = on;
    }

    /// Drop every registered prefix, unpinning its blocks (storage
    /// frees as the last attached session exits; immediately if none).
    pub fn clear_prefix_cache(&mut self) {
        if let Some(reg) = &self.prefix {
            reg.clear();
        }
        self.publish_arena_gauges();
    }

    /// Demote cold clusters engine-wide (spill-policy order, sessions
    /// in id order for determinism) until at least `need` hot blocks
    /// were freed or nothing demotable remains. Returns blocks freed.
    fn make_room(&mut self, need: usize) -> usize {
        let Some(policy) = self.spill_policy.clone() else {
            return 0;
        };
        let mut freed = 0usize;
        let mut ids: Vec<u64> = self.states.keys().copied().collect();
        ids.sort_unstable();
        'outer: for id in ids {
            let st = self.states.get_mut(&id).unwrap();
            for slot in 0..st.indexes.len() {
                if freed >= need {
                    break 'outer;
                }
                let (n, demoted) = st.indexes[slot].demote_until(policy.as_ref(), need - freed);
                freed += n;
                for c in demoted {
                    // drop the demoted blocks' GPU-cache copies and mark
                    // their mapping homes cold
                    st.buffers[slot].note_demoted(st.indexes[slot].cluster_blocks(c));
                }
            }
        }
        if freed > 0 {
            self.metrics.inc("spill_make_room_blocks", freed as u64);
        }
        freed
    }

    /// Promote the clusters each batch head's estimator selected last
    /// step (its `recent_clusters`) back into the hot tier before
    /// assembly — consuming the pages the async prefetcher staged. A
    /// full hot tier demotes colder clusters first (bounded retries);
    /// clusters that still cannot fit stay cold and assembly serves
    /// them through the spill tier (counted as cold-hit stalls).
    fn promote_prefetched(&mut self, ids: &[u64]) {
        for &id in ids {
            let n_slots = match self.states.get(&id) {
                Some(st) => st.indexes.len(),
                None => continue,
            };
            for slot in 0..n_slots {
                let wanted = self.states[&id].indexes[slot].recent_clusters();
                for c in wanted {
                    let mut attempts = 0;
                    loop {
                        let (n, _staged, err) = {
                            let st = self.states.get_mut(&id).unwrap();
                            st.indexes[slot].promote_cluster(c)
                        };
                        if n > 0 {
                            let st = self.states.get_mut(&id).unwrap();
                            // a partial promotion leaves some blocks cold:
                            // only the actually-hot ones flip their homes
                            let hot_refs: Vec<crate::kvcache::BlockRef> = st.indexes[slot]
                                .cluster_blocks(c)
                                .iter()
                                .copied()
                                .filter(|r| st.indexes[slot].store().is_hot(*r))
                                .collect();
                            st.buffers[slot].note_promoted(&hot_refs);
                        }
                        match err {
                            None => break,
                            Some(AllocError::ArenaFull { .. }) => {
                                attempts += 1;
                                if attempts > 2 || self.make_room(8) == 0 {
                                    break;
                                }
                            }
                            Some(_) => break,
                        }
                    }
                }
            }
        }
    }

    /// Toggle the thread-pool head fan-out (on by default when the
    /// buffer config has more than one CPU thread). The sequential path
    /// produces bit-identical execution buffers — this only trades
    /// wall-clock.
    pub fn set_parallel_assembly(&mut self, parallel: bool) {
        self.assembler.set_parallel(parallel);
    }

    pub fn mode(&self) -> AttnMode {
        self.mode
    }

    pub fn lm(&mut self) -> &mut TinyLm {
        &mut self.lm
    }

    pub fn n_sessions(&self) -> usize {
        self.states.len()
    }

    /// Aggregate wave-buffer hit ratio across all sessions/heads.
    pub fn buffer_hit_ratio(&self) -> f64 {
        let mut h = 0u64;
        let mut m = 0u64;
        for s in self.states.values() {
            for b in &s.buffers {
                h += b.stats().hit_blocks.load(std::sync::atomic::Ordering::Relaxed);
                m += b.stats().miss_blocks.load(std::sync::atomic::Ordering::Relaxed);
            }
        }
        if h + m == 0 {
            1.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Prefill one prompt (length must be a prefill bucket); builds the
    /// session's wave indexes via segmented clustering and returns the
    /// first generated token. Default-tenant form of
    /// [`LiveEngine::prefill_for`].
    pub fn prefill(&mut self, id: u64, prompt: &[i32]) -> Result<i32> {
        self.prefill_for(id, DEFAULT_TENANT, prompt)
    }

    /// The chain geometry prefix hashing uses (mirrors this engine's
    /// zone config so links align with build segments).
    fn chain_geometry(&self) -> ChainGeometry {
        ChainGeometry {
            sink: self.zcfg.steady_sink,
            segment: self.zcfg.build_segment,
            local: self.zcfg.steady_local,
        }
    }

    /// Tenant-attributed prefill. If the arena refuses a KV block
    /// (capacity cap or tenant quota), every block the partial session
    /// checked out is returned and a typed error propagates — the engine
    /// never panics on exhaustion; the scheduler's admission gate is
    /// expected to keep this path cold.
    ///
    /// With prefix sharing armed ([`LiveEngine::enable_prefix_sharing`])
    /// the prompt is matched against the registry first: the longest
    /// registered prefix grafts as shared, refcounted blocks (no
    /// re-clustering, no fresh checkouts — a prefix shared by N
    /// sessions is resident once), and an unmatched prompt seals and
    /// registers its own prefix for later sessions.
    pub fn prefill_for(&mut self, id: u64, tenant: TenantId, prompt: &[i32]) -> Result<i32> {
        // One maximal chunk: the chunked path IS the monolithic path,
        // so the two can never drift apart bit-wise.
        let mut job = self.prefill_start(id, tenant, prompt)?;
        while !self.prefill_advance(&mut job, usize::MAX)? {}
        self.prefill_finish(job)
    }

    /// Begin a resumable chunked prefill: runs the LM forward, matches
    /// the prefix registry, and opens every (layer, kv-head) wave index
    /// as a chunked build. No KV rows are fed yet — drive the returned
    /// job with [`LiveEngine::prefill_advance`], then register it with
    /// [`LiveEngine::prefill_finish`]. Dropping the job instead aborts
    /// it and returns every checked-out block to the arena.
    pub fn prefill_start(
        &mut self,
        id: u64,
        tenant: TenantId,
        prompt: &[i32],
    ) -> Result<PrefillJob> {
        let t0 = Instant::now();
        let (kc, vc, logits) = self.lm.prefill(prompt)?;
        // kc/vc: [L, 1, KVH, T, d]
        let (l_n, kvh, t, d) =
            (kc.shape()[0], kc.shape()[2], kc.shape()[3], kc.shape()[4]);
        // Longest-prefix match (counts hits/misses). Content-derived
        // seeds make the graft bit-identical to an unshared build.
        let matched: Option<PrefixMatch> = match &self.prefix {
            Some(reg) => {
                // the registry is engine-owned, so slot counts always
                // agree — but guard a mismatched entry into a plain
                // build (and count it as a miss: nothing was served)
                let m = reg
                    .match_longest(prompt)
                    .filter(|m| m.slots.len() == l_n * kvh);
                match &m {
                    Some(m) => {
                        self.metrics.inc("prefix_hits", 1);
                        self.metrics.inc("prefix_matched_tokens", m.covered as u64);
                    }
                    None => self.metrics.inc("prefix_misses", 1),
                }
                m
            }
            None => None,
        };
        let base_seed =
            if self.content_seeds { self.chain_geometry().content_seed(prompt) } else { id };
        let mut indexes = Vec::with_capacity(l_n * kvh);
        let mut k_full = Vec::new();
        let mut v_full = Vec::new();
        let t_cap = self.lm.buckets.attn_full_t;
        for layer in 0..l_n {
            if self.mode == AttnMode::Full {
                let mut kf = vec![0.0f32; kvh * t_cap * d];
                let mut vf = vec![0.0f32; kvh * t_cap * d];
                for h in 0..kvh {
                    let ks = kc.row(&[layer, 0, h]);
                    let vs = vc.row(&[layer, 0, h]);
                    kf[h * t_cap * d..h * t_cap * d + t * d].copy_from_slice(ks);
                    vf[h * t_cap * d..h * t_cap * d + t * d].copy_from_slice(vs);
                }
                k_full.push(kf);
                v_full.push(vf);
            }
            for h in 0..kvh {
                let seed = base_seed ^ ((layer * kvh + h) as u64).wrapping_mul(0x9e3779b1);
                // The grafted prefix attaches as shared, refcounted
                // block views right here (no fresh checkouts); new rows
                // arrive chunk by chunk through `prefill_advance`.
                let mut idx = match &matched {
                    Some(m) => WaveIndex::begin_build_grafted_in_for(
                        &self.arena,
                        tenant,
                        self.zcfg.clone(),
                        &m.slots[layer * kvh + h],
                        m.covered,
                        t,
                        seed,
                    ),
                    None => WaveIndex::begin_build_in_for(
                        &self.arena,
                        tenant,
                        self.zcfg.clone(),
                        t,
                        seed,
                    ),
                };
                if let Some(p) = &self.spill_policy {
                    idx.set_spill_policy(Some(Arc::clone(p)));
                }
                idx.set_lossy_cos_floor(self.lossy_cos_floor);
                indexes.push(idx);
            }
        }
        let first = TinyLm::greedy(&logits)[0];
        Ok(PrefillJob {
            id,
            tenant,
            prompt: prompt.to_vec(),
            kc,
            vc,
            first,
            indexes,
            k_full,
            v_full,
            matched_covered: matched.map(|m| m.covered),
            fed: 0,
            t,
            spent_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Advance an open prefill by up to `chunk_tokens` prompt rows on
    /// every (layer, kv-head) slot, clustering whatever build segments
    /// become complete — the bounded unit of work the scheduler
    /// interleaves with decode steps. Returns `true` once every prompt
    /// token has been fed (finish the job next).
    ///
    /// On an arena refusal (capacity cap or tenant quota with nothing
    /// left to demote) the typed error propagates and the job stays
    /// resumable: rows already buffered are kept, and a later call
    /// retries exactly the missing work. Dropping the job instead
    /// returns every checked-out block to the arena.
    pub fn prefill_advance(&mut self, job: &mut PrefillJob, chunk_tokens: usize) -> Result<bool> {
        if job.fed == job.t {
            return Ok(true);
        }
        let t0 = Instant::now();
        let c = chunk_tokens.max(1).min(job.t - job.fed);
        let target = job.fed + c;
        let d = job.kc.shape()[4];
        let kvh = job.kc.shape()[2];
        // Taken out of the engine for the chunk and restored at the
        // end: a warm chunk allocates nothing engine-side.
        let mut build = std::mem::take(&mut self.step.build);
        for s in 0..job.indexes.len() {
            let (layer, h) = (s / kvh, s % kvh);
            if !job.indexes[s].build_in_progress() {
                // closed by the final chunk of an earlier, partially
                // failed advance — nothing left to feed this slot
                continue;
            }
            // Tiered arena: make hot room for this slot's chunk up
            // front — full hot tier means "demote, then retry", not
            // "refuse and defer".
            if self.spill_enabled() {
                if let Some(cap) = self.arena.capacity_blocks() {
                    let tpb = self.arena.tokens_per_block();
                    let need =
                        c.div_ceil(tpb) + c.div_ceil(self.zcfg.tokens_per_cluster) + 2;
                    let headroom = cap.saturating_sub(self.arena.live_blocks());
                    if headroom < need {
                        self.make_room(need - headroom);
                    }
                }
            }
            loop {
                // The index tracks what it has already buffered, so a
                // retry after a mid-segment refusal feeds only the
                // missing rows (an empty feed retries the pending
                // segment).
                let already = job.t - job.indexes[s].build_remaining();
                let (lo, hi) =
                    if already < target { (already * d, target * d) } else { (0, 0) };
                let res = {
                    let keys = &job.kc.row(&[layer, 0, h])[lo..hi];
                    let vals = &job.vc.row(&[layer, 0, h])[lo..hi];
                    job.indexes[s].try_feed_build_with(keys, vals, &mut build)
                };
                match res {
                    Ok(()) => break,
                    Err(e) => {
                        let retry = matches!(e, AllocError::ArenaFull { .. })
                            && self.spill_enabled()
                            && self.make_room(64) > 0;
                        if !retry {
                            self.step.build = build;
                            self.metrics.inc("prefill_alloc_failures", 1);
                            self.publish_arena_gauges();
                            return Err(anyhow!(
                                "prefill {} (tenant {}): {e}",
                                job.id,
                                job.tenant
                            ));
                        }
                    }
                }
            }
        }
        self.step.build = build;
        job.fed = target;
        let dt = t0.elapsed().as_secs_f64();
        job.spent_s += dt;
        self.metrics.observe("prefill_chunk_s", dt);
        self.metrics.inc("prefill_chunks", 1);
        Ok(job.fed == job.t)
    }

    /// Register a completed chunked prefill as a live session: creates
    /// the wave buffers (and shared GPU cache slots), seals & registers
    /// an unmatched prefix, and installs the session state. Returns the
    /// first generated token, exactly as [`LiveEngine::prefill_for`]
    /// does. Errors (without consuming state the arena cares about — the
    /// job is dropped) if called before every chunk was fed.
    pub fn prefill_finish(&mut self, job: PrefillJob) -> Result<i32> {
        if job.fed < job.t {
            return Err(anyhow!(
                "prefill {}: finish with {}/{} tokens fed",
                job.id,
                job.fed,
                job.t
            ));
        }
        let t0 = Instant::now();
        let PrefillJob {
            id,
            prompt,
            first,
            mut indexes,
            k_full,
            v_full,
            matched_covered,
            t,
            spent_s,
            ..
        } = job;
        debug_assert!(
            indexes.iter().all(|ix| !ix.build_in_progress()),
            "all chunks fed but a build is still open"
        );
        let d = self.arena.d();
        let mut buffers = Vec::with_capacity(indexes.len());
        for (slot_i, idx) in indexes.iter().enumerate() {
            let cap = WaveBuffer::capacity_for(&self.bcfg, t, idx.store().tokens_per_block());
            let mut buf = WaveBuffer::new(
                self.bcfg.clone(),
                d,
                idx.store().tokens_per_block(),
                cap,
                Arc::clone(&self.pool),
            );
            if self.prefix.is_some() {
                // one cross-session cache per head slot: a prefix
                // shared by N sessions occupies one GPU slot set.
                // Sized from the engine-level byte budget (or the
                // max context bucket without one), never from this
                // prompt — the cache outlives every session, so the
                // first arrival's length must not pin it.
                if self.shared_caches.len() <= slot_i {
                    let tpb = self.arena.tokens_per_block();
                    self.shared_caches.push(Arc::new(SharedBlockCache::new(
                        self.bcfg.policy,
                        self.shared_slot_capacity(),
                        2 * tpb * d,
                    )));
                }
                buf.set_shared_cache(Arc::clone(&self.shared_caches[slot_i]));
            }
            buf.register_index(idx);
            buffers.push(buf);
        }
        // Seal & register: an unmatched (or longer-than-matched) prefix
        // becomes available to every later session. Sealing converts
        // this session's prefix blocks into shared views in place — it
        // keeps serving them.
        if let Some(reg) = self.prefix.clone() {
            let clustered =
                indexes.first().map(|ix| ix.clustered_prefix_tokens()).unwrap_or(0);
            let best = reg
                .links(&prompt)
                .into_iter()
                .filter(|&(covered, _)| covered <= clustered)
                .next_back();
            if let Some((covered, key)) = best {
                let longer = matched_covered.map(|mc| covered > mc).unwrap_or(true);
                if longer && !reg.contains(key) {
                    let slots: Vec<crate::kvcache::SealedSlot> =
                        indexes.iter_mut().map(|ix| ix.seal_prefix(covered)).collect();
                    if reg.register(key, covered, slots) {
                        self.metrics.inc("prefix_registered", 1);
                    }
                }
            }
        }
        self.states.insert(
            id,
            SessionState { indexes, buffers, k_full, v_full, len: t, last_token: first },
        );
        self.metrics.observe("prefill_s", spent_s + t0.elapsed().as_secs_f64());
        self.metrics.inc("prefills", 1);
        self.publish_arena_gauges();
        Ok(first)
    }

    fn publish_arena_gauges(&self) {
        self.metrics.set_gauge("arena_live_blocks", self.arena.live_blocks() as u64);
        self.metrics.set_gauge("arena_live_bytes", self.arena.live_bytes() as u64);
        self.metrics.set_gauge("arena_free_blocks", self.arena.free_blocks() as u64);
        self.metrics.set_gauge("arena_resident_bytes", self.arena.resident_bytes() as u64);
        self.metrics.set_gauge_max("arena_live_blocks_peak", self.arena.live_blocks() as u64);
        if let Some(cap) = self.arena.capacity_blocks() {
            self.metrics.set_gauge("arena_capacity_blocks", cap as u64);
        }
        // Cold-tier gauges (zero everywhere in single-tier runs).
        self.metrics.set_gauge("arena_cold_blocks", self.arena.cold_blocks() as u64);
        self.metrics.set_gauge("arena_cold_bytes", self.arena.cold_bytes() as u64);
        self.metrics.set_gauge("arena_demoted_total", self.arena.demoted_total());
        self.metrics.set_gauge("arena_promoted_total", self.arena.promoted_total());
        // Cross-step prefetch effectiveness: promotions whose page was
        // already staged when the promoting step consumed it.
        self.metrics.set_ratio_gauge(
            "spill_promote_staged_pct",
            self.arena.promoted_staged_total(),
            self.arena.promoted_total(),
        );
        // Measured intra-step spill overlap: of every cold-tier page
        // read on the decode path, the fraction served from the I/O
        // lane's staging area — reads whose file I/O completed under
        // attention/select compute instead of stalling the gather.
        self.metrics.set_ratio_gauge(
            "spill_overlap_pct",
            self.arena.cold_reads_staged(),
            self.arena.cold_reads_total(),
        );
        self.metrics.set_gauge("spill_staged_blocks", self.arena.staged_blocks() as u64);
        self.metrics
            .set_gauge("spill_staged_stale_dropped", self.arena.staged_stale_dropped());
        // Spill-codec gauges (with the Exact codec: compressed = 0 and
        // physical = logical + page headers).
        let spill = self.arena.spill();
        self.metrics.set_gauge("spill_compressed_blocks", spill.compressed_blocks() as u64);
        self.metrics.set_gauge("spill_logical_bytes", spill.logical_bytes() as u64);
        self.metrics.set_gauge("spill_physical_bytes", spill.physical_bytes() as u64);
        // achieved compression as integer percent (100 = incompressible)
        self.metrics.set_ratio_gauge(
            "spill_compression_pct",
            spill.physical_bytes() as u64,
            spill.logical_bytes() as u64,
        );
        self.metrics
            .set_gauge_max("arena_total_live_blocks_peak", self.arena.total_live_blocks() as u64);
        // Prefix-sharing gauges (zero everywhere with sharing unarmed).
        let shared = self.arena.shared_blocks_live() as u64;
        let refs = self.arena.shared_session_refs() as u64;
        self.metrics.set_gauge("shared_blocks_live", shared);
        self.metrics.set_gauge("shared_block_refs", refs);
        // dedup ratio as integer percent: N sessions sharing every
        // shared block reads 100·N
        self.metrics.set_ratio_gauge("dedup_ratio_pct", refs, shared);
        self.metrics.set_gauge_max("shared_blocks_live_peak", shared);
        self.metrics.set_gauge_max("shared_block_refs_peak", refs);
    }

    /// Set the engine-level byte budget for the cross-session shared
    /// GPU block caches (split evenly across every (layer, kv-head)
    /// slot). `None` restores max-context-bucket sizing. Applies to
    /// slots created after the call — set it before the first prefill.
    pub fn set_shared_cache_budget_bytes(&mut self, budget: Option<usize>) {
        self.shared_cache_budget = budget;
    }

    /// Blocks one shared-cache slot may hold under the current sizing
    /// rule.
    fn shared_slot_capacity(&self) -> usize {
        let tpb = self.arena.tokens_per_block();
        match self.shared_cache_budget {
            Some(budget) => shared_slot_capacity_for(
                budget,
                self.lm.cfg.n_layers * self.lm.cfg.kv_heads,
                tpb,
                self.lm.cfg.d_head,
            ),
            None => WaveBuffer::capacity_for(&self.bcfg, self.lm.buckets.attn_full_t, tpb),
        }
    }

    /// Cap the engine arena's live-block occupancy (`None` = unbounded).
    pub fn set_arena_capacity_blocks(&self, cap: Option<usize>) {
        self.arena.set_capacity_blocks(cap);
        self.publish_arena_gauges();
    }

    /// Set a tenant's block quota on the engine arena.
    pub fn set_tenant_quota_blocks(&self, tenant: TenantId, quota: Option<usize>) {
        self.arena.set_tenant_quota(tenant, quota);
    }

    /// Apply a [`CapacityConfig`]'s byte budgets to the engine arena:
    /// the arena cap, plus the per-tenant quota for each tenant in
    /// `tenants`.
    pub fn apply_capacity(&self, cap: &CapacityConfig, tenants: &[TenantId]) {
        let bb = self.arena.block_bytes();
        self.arena.set_capacity_blocks(cap.capacity_blocks(bb));
        if let Some(q) = cap.quota_blocks(bb) {
            for &t in tenants {
                self.arena.set_tenant_quota(t, Some(q));
            }
        }
        self.publish_arena_gauges();
    }

    /// Admission-gate parameters matching this engine's KV geometry
    /// (`heads = layers × kv-heads`, the arena's block size), with the
    /// headroom and estimate-fudge tuning taken from `cap` (the fudge
    /// covers cluster tail-block fragmentation — clusters never share
    /// blocks — plus decode-time update segments).
    pub fn admission_config(&self, cap: &CapacityConfig) -> AdmissionConfig {
        AdmissionConfig {
            heads: self.lm.cfg.n_layers * self.lm.cfg.kv_heads,
            tokens_per_block: self.arena.tokens_per_block(),
            headroom_frac: cap.admit_headroom_frac,
            est_fudge: cap.est_fudge,
            tiered: self.spill_enabled(),
        }
    }

    /// One decode step for the sessions in `ids`, padded to `bucket`.
    /// Returns the newly decoded token per session (in `ids` order).
    pub fn decode_step(&mut self, ids: &[u64], bucket: usize) -> Result<Vec<i32>> {
        let t0 = Instant::now();
        let b = bucket;
        if ids.is_empty() || ids.len() > b {
            return Err(anyhow!("bad batch: {} ids, bucket {b}", ids.len()));
        }
        for (a, id) in ids.iter().enumerate() {
            if !self.states.contains_key(id) {
                return Err(anyhow!("unknown session {id}"));
            }
            // uniqueness keeps the parallel per-session append fan-out
            // alias-free (the scheduler never emits duplicates)
            if ids[..a].contains(id) {
                return Err(anyhow!("duplicate session {id} in batch"));
            }
        }
        if self.spill_enabled() {
            // Promote the clusters each head's estimator selected last
            // step, consuming the pages the async prefetcher staged —
            // the promotion happened off the critical path; this is
            // just the cheap install.
            self.promote_prefetched(ids);
            // New staging epoch: pages staged this step or last step
            // stay servable (double-buffered — in-flight reads from the
            // previous selection still land usefully); anything older
            // was never consumed and is dropped, so the staging
            // footprint stays O(depth) over a long run, not O(steps).
            self.arena.begin_staging_epoch();
        }
        // Pad rows replicate the first live session (outputs discarded).
        let row_id = |i: usize| ids[i.min(ids.len() - 1)];

        // Take the step scratch out of the engine: steady-state decode
        // at a fixed batch width reallocates none of these. An error
        // path below drops it — the next step simply reallocates.
        let mut ss = std::mem::take(&mut self.step);
        ss.tokens.clear();
        ss.tokens.extend((0..b).map(|i| self.states[&row_id(i)].last_token));
        ss.pos.clear();
        ss.pos.extend((0..b).map(|i| self.states[&row_id(i)].len as i32));

        let mut hidden = self.lm.embed(&ss.tokens)?;
        let (kvh, d, group) = (self.lm.cfg.kv_heads, self.lm.cfg.d_head, self.lm.cfg.group());
        let (ne, m_cap) = (self.lm.buckets.wave_ne, self.lm.buckets.wave_m);
        let n_layers = self.lm.cfg.n_layers;
        let shape = AssembleShape { ne, m_cap, d, group };
        // Reused across layers AND steps: every (row, head) slice is
        // fully rewritten by each layer's assembly, so the cached tensor
        // only needs the right batch width.
        let mut wi = match self.mode {
            AttnMode::Wave => Some(match ss.wi.take() {
                Some(w) if ss.wi_rows == b => w,
                _ => WaveInputs::zeros(b, kvh, ne, m_cap, d),
            }),
            AttnMode::Full => None,
        };
        let mut assemble_s = 0.0f64;
        let mut select_s = 0.0f64;
        let mut gather_s = 0.0f64;
        let mut merge_s = 0.0f64;

        for layer in 0..n_layers {
            let (q, k, v) = self.lm.qkv(layer, &hidden, &ss.pos)?;
            // Append the new token's KV (live rows only, once per
            // session). Sessions are disjoint `&mut`s, so the per-
            // session appends fan out across the pool (ROADMAP "fan-out
            // past assembly"); the serial path runs the identical
            // closure, so per-session state is bit-identical either way
            // (property-tested in tests/arena.rs).
            {
                let mode = self.mode;
                let t_cap = self.lm.buckets.attn_full_t;
                let mut row_states: Vec<(usize, u64, &mut SessionState)> = self
                    .states
                    .iter_mut()
                    .filter_map(|(sid, st)| {
                        let sid = *sid;
                        ids.iter().position(|x| *x == sid).map(|i| (i, sid, st))
                    })
                    .collect();
                row_states.sort_unstable_by_key(|e| e.0);
                let errs: Mutex<Vec<(u64, AllocError)>> = Mutex::new(Vec::new());
                let kt = &k;
                let vt = &v;
                let append_one = |_t: usize, e: &mut (usize, u64, &mut SessionState)| {
                    let (i, id, st) = (e.0, e.1, &mut *e.2);
                    for h in 0..kvh {
                        let key = kt.row(&[i, h]);
                        let val = vt.row(&[i, h]);
                        match mode {
                            AttnMode::Wave => {
                                let slot = layer * kvh + h;
                                if let Err(err) = st.indexes[slot].try_append(key, val) {
                                    errs.lock().unwrap().push((id, err));
                                    return;
                                }
                                st.buffers[slot].sync_new_clusters(&st.indexes[slot]);
                            }
                            AttnMode::Full => {
                                let off = h * t_cap * d + st.len * d;
                                st.k_full[layer][off..off + d].copy_from_slice(key);
                                st.v_full[layer][off..off + d].copy_from_slice(val);
                            }
                        }
                    }
                };
                if self.assembler.parallel() && row_states.len() > 1 {
                    self.pool.scope_for_each_mut(&mut row_states, &append_one);
                } else {
                    for ti in 0..row_states.len() {
                        append_one(ti, &mut row_states[ti]);
                    }
                }
                drop(row_states);
                if let Some((id, e)) = errs.into_inner().unwrap().into_iter().next() {
                    return Err(anyhow!("session {id}: decode kv append refused: {e}"));
                }
            }

            let ctx = match self.mode {
                AttnMode::Wave => {
                    let wi = wi.as_mut().unwrap();
                    // Group queries per (row, head), flat [b*kvh, G, d]:
                    // zone selection scores each cluster by the MAX over
                    // the group's queries (GQA — each query head's heavy
                    // hitters stay retrievable). The staging vector is
                    // step-scratch and every element is overwritten.
                    ss.qg_all.resize(b * kvh * group * d, 0.0);
                    for i in 0..b {
                        for h in 0..kvh {
                            for g in 0..group {
                                let base = ((i * kvh + h) * group + g) * d;
                                ss.qg_all[base..base + d]
                                    .copy_from_slice(q.row(&[i, h, g]));
                            }
                        }
                    }
                    // One task per (row, head): fan the zone selection +
                    // exec-buffer gather across the engine thread pool.
                    let states = &self.states;
                    let tasks: Vec<HeadTask<'_>> = (0..b * kvh)
                        .map(|t| {
                            let st = &states[&row_id(t / kvh)];
                            let slot = layer * kvh + t % kvh;
                            HeadTask { index: &st.indexes[slot], buffer: &st.buffers[slot] }
                        })
                        .collect();
                    let t_as = Instant::now();
                    let stats = self.assembler.assemble_into(&tasks, &ss.qg_all, shape, wi);
                    assemble_s += t_as.elapsed().as_secs_f64();
                    select_s += stats.select_ns as f64 * 1e-9;
                    gather_s += stats.gather_ns as f64 * 1e-9;
                    if self.spill_policy.is_some() {
                        // Async prefetch: stage the cold blocks of the
                        // clusters each head's estimator just selected
                        // for the next step. The pool job's spill reads
                        // overlap this layer's attention + MLP the way
                        // the wave buffer overlaps PCIe with compute;
                        // the next decode step installs the staged
                        // pages via `promote_prefetched`.
                        let mut want_cold: Vec<u64> = Vec::new();
                        for task in &tasks {
                            for c in task.index.recent_clusters() {
                                for r in task.index.cluster_blocks(c) {
                                    if !task.index.store().is_hot(*r) {
                                        want_cold.push(r.block);
                                    }
                                }
                            }
                        }
                        if !want_cold.is_empty() {
                            want_cold.sort_unstable();
                            want_cold.dedup();
                            self.metrics
                                .inc("spill_prefetch_blocks", want_cold.len() as u64);
                            let arena = Arc::clone(&self.arena);
                            // Dedicated I/O lane: a backlog of slow
                            // cold-tier reads can never occupy compute
                            // workers, and the next layer's fan-out can
                            // never queue behind these reads.
                            self.pool.submit_io(move || {
                                for bid in want_cold {
                                    arena.prefetch(bid);
                                }
                            });
                        }
                    }
                    drop(tasks);
                    self.metrics.inc("pcie_bytes", stats.pcie_bytes as u64);
                    self.metrics.inc("hit_blocks", stats.hit_blocks as u64);
                    self.metrics.inc("miss_blocks", stats.miss_blocks as u64);
                    self.metrics.inc("cold_hit_blocks", stats.cold_blocks as u64);
                    self.metrics.inc("cold_staged_blocks", stats.cold_staged_blocks as u64);
                    self.metrics.inc("spill_bytes", stats.spill_bytes as u64);
                    self.metrics.inc("assembled_heads", (b * kvh) as u64);
                    let t_mg = Instant::now();
                    let ctx = self.lm.attn_wave(&q, wi)?;
                    merge_s += t_mg.elapsed().as_secs_f64();
                    ctx
                }
                AttnMode::Full => {
                    let t_cap = self.lm.buckets.attn_full_t;
                    let row = kvh * t_cap * d;
                    let mut kb = vec![0.0f32; b * row];
                    let mut vb = vec![0.0f32; b * row];
                    let mut lens = vec![0i32; b];
                    for (i, len) in lens.iter_mut().enumerate() {
                        *len = (self.states[&row_id(i)].len + 1) as i32;
                    }
                    // Fan the full-attention KV broadcast across the
                    // pool: each task copies one row's [KVH, T, d]
                    // cache into its disjoint output slice (ROADMAP
                    // "fan-out past assembly"); serial and parallel
                    // paths write identical bytes.
                    let states = &self.states;
                    let fill = |i: usize, out: &mut (&mut [f32], &mut [f32])| {
                        let st = &states[&row_id(i)];
                        out.0.copy_from_slice(&st.k_full[layer]);
                        out.1.copy_from_slice(&st.v_full[layer]);
                    };
                    let mut rows: Vec<(&mut [f32], &mut [f32])> =
                        kb.chunks_mut(row).zip(vb.chunks_mut(row)).collect();
                    if self.assembler.parallel() && b > 1 {
                        self.pool.scope_for_each_mut(&mut rows, &fill);
                    } else {
                        for (i, r) in rows.iter_mut().enumerate() {
                            fill(i, r);
                        }
                    }
                    drop(rows);
                    self.lm.attn_full(&q, &kb, &vb, &lens)?
                }
            };
            hidden = self.lm.mlp(layer, &hidden, &ctx)?;
        }

        let logits = self.lm.logits(&hidden)?;
        let all = TinyLm::greedy(&logits);
        let mut out = Vec::with_capacity(ids.len());
        for (i, id) in ids.iter().enumerate() {
            let st = self.states.get_mut(id).unwrap();
            st.last_token = all[i];
            st.len += 1;
            out.push(all[i]);
        }
        self.metrics.observe("decode_step_s", t0.elapsed().as_secs_f64());
        if self.mode == AttnMode::Wave {
            self.metrics.observe("assemble_s", assemble_s);
            // Decode phase report: zone selection vs gather/pack (both
            // inside assemble_s) vs the tripartite-merge kernel call.
            self.metrics.observe("select_s", select_s);
            self.metrics.observe("gather_s", gather_s);
            self.metrics.observe("merge_s", merge_s);
            let key = if self.assembler.parallel() && b * kvh > 1 {
                "assembly_parallel_steps"
            } else {
                "assembly_serial_steps"
            };
            self.metrics.inc(key, 1);
        }
        // Hand the step scratch (and the kernel input tensor) back for
        // the next step.
        ss.wi = wi;
        ss.wi_rows = b;
        self.step = ss;
        self.metrics.inc("decode_steps", 1);
        self.metrics.inc("decoded_tokens", ids.len() as u64);
        // decode-time appends grow the arena; keep the occupancy gauges
        // (and the peak tracker the capacity asserts read) current
        self.publish_arena_gauges();
        Ok(out)
    }

    /// Assemble one (sequence, head) slice of the wave-attention inputs
    /// on the caller thread — the single-head form of the batch fan-out
    /// in `decode_step` (same code path via [`assemble_head`], so the
    /// two are bit-identical; used by fidelity tests).
    fn assemble_head(
        &mut self,
        id: u64,
        layer: usize,
        h: usize,
        row: usize,
        q: &Tensor,
        wi: &mut WaveInputs,
    ) -> Result<()> {
        let (kvh, d, group) = (self.lm.cfg.kv_heads, self.lm.cfg.d_head, self.lm.cfg.group());
        let (ne, m_cap) = (self.lm.buckets.wave_ne, self.lm.buckets.wave_m);
        let shape = AssembleShape { ne, m_cap, d, group };
        let slot = layer * kvh + h;

        let mut qg = vec![0.0f32; group * d];
        for g in 0..group {
            qg[g * d..(g + 1) * d].copy_from_slice(q.row(&[row, h, g]));
        }

        let st = self.states.get(&id).ok_or_else(|| anyhow!("unknown session {id}"))?;
        let task = HeadTask { index: &st.indexes[slot], buffer: &st.buffers[slot] };
        let t = row * kvh + h;
        let mut out = HeadSlices {
            kx: &mut wi.kx[t * ne * d..(t + 1) * ne * d],
            vx: &mut wi.vx[t * ne * d..(t + 1) * ne * d],
            kmask: &mut wi.kmask[t * ne..(t + 1) * ne],
            cent: &mut wi.cent[t * m_cap * d..(t + 1) * m_cap * d],
            vsum: &mut wi.vsum[t * m_cap * d..(t + 1) * m_cap * d],
            csize: &mut wi.csize[t * m_cap..(t + 1) * m_cap],
            emask: &mut wi.emask[t * m_cap..(t + 1) * m_cap],
        };
        let mut eb = ExecBuffer::new(d);
        let stats = assemble_head(task, &qg, shape, &mut self.scratch, &mut eb, &mut out);
        self.metrics.inc("pcie_bytes", stats.pcie_bytes as u64);
        self.metrics.inc("hit_blocks", stats.hit_blocks as u64);
        self.metrics.inc("miss_blocks", stats.miss_blocks as u64);
        Ok(())
    }

    /// Session context length (prompt + generated).
    pub fn session_len(&self, id: u64) -> Option<usize> {
        self.states.get(&id).map(|s| s.len)
    }

    /// Tear down a finished session: drop its indexes/buffers and
    /// return every KV block it held to the engine arena's free-list.
    /// Returns how many blocks were reclaimed (0 for unknown ids).
    pub fn finish_session(&mut self, id: u64) -> usize {
        let before = self.arena.live_blocks();
        if self.states.remove(&id).is_none() {
            return 0;
        }
        let freed = before - self.arena.live_blocks();
        self.metrics.inc("sessions_finished", 1);
        self.metrics.inc("arena_reclaimed_blocks", freed as u64);
        self.publish_arena_gauges();
        freed
    }

    /// Drop a finished session, releasing its memory (alias kept for
    /// older callers; use [`LiveEngine::finish_session`]).
    pub fn evict_session(&mut self, id: u64) {
        self.finish_session(id);
    }

    /// Overwrite the token the next decode step will consume (teacher
    /// forcing — used to measure per-step prediction agreement between
    /// attention modes without autoregressive divergence).
    pub fn force_token(&mut self, id: u64, token: i32) {
        if let Some(st) = self.states.get_mut(&id) {
            st.last_token = token;
        }
    }

    /// Serialize a session's complete KV + index state for live
    /// migration (DESIGN.md §2 "Cluster serving & migration"): each
    /// (layer, kv-head) slot's wave index exports its clusters through
    /// the bit-exact spill page format plus its metadata (centroids,
    /// vsums, positions, seed), so an [`LiveEngine::import_session`] on
    /// another replica resumes bit-identically. Derived perf-only state
    /// (wave-buffer cache residency, access epochs, hot/cold placement)
    /// is deliberately absent — it rebuilds cold on the target and never
    /// affects token bits. The source session stays live; migration
    /// callers pair this with [`LiveEngine::finish_session`].
    pub fn export_session(&self, id: u64) -> Option<SessionSnapshot> {
        let st = self.states.get(&id)?;
        let snap = SessionSnapshot {
            len: st.len,
            last_token: st.last_token,
            indexes: st.indexes.iter().map(|ix| ix.export_state()).collect(),
            k_full: st.k_full.clone(),
            v_full: st.v_full.clone(),
        };
        self.metrics.inc("sessions_exported", 1);
        self.metrics.inc("migration_bytes_out", snap.payload_bytes() as u64);
        Some(snap)
    }

    /// Rebuild a migrated session on this replica from its snapshot.
    /// The wave indexes re-pack into this engine's block geometry (the
    /// source's block size may differ); wave buffers start cold. A
    /// failed import (corrupt stream, geometry mismatch, arena refusal)
    /// leaves this engine unchanged — every block the partial rebuild
    /// checked out is returned.
    pub fn import_session(
        &mut self,
        id: u64,
        tenant: TenantId,
        snap: &SessionSnapshot,
    ) -> Result<()> {
        if self.states.contains_key(&id) {
            return Err(anyhow!("import {id}: session already live on this replica"));
        }
        let (l_n, kvh, d) =
            (self.lm.cfg.n_layers, self.lm.cfg.kv_heads, self.lm.cfg.d_head);
        match self.mode {
            AttnMode::Wave => {
                if snap.indexes.len() != l_n * kvh {
                    return Err(anyhow!(
                        "import {id}: snapshot has {} index slots, engine needs {}",
                        snap.indexes.len(),
                        l_n * kvh
                    ));
                }
            }
            AttnMode::Full => {
                let t_cap = self.lm.buckets.attn_full_t;
                if snap.k_full.len() != l_n
                    || snap.v_full.len() != l_n
                    || snap.k_full.iter().any(|l| l.len() != kvh * t_cap * d)
                    || snap.v_full.iter().any(|l| l.len() != kvh * t_cap * d)
                {
                    return Err(anyhow!(
                        "import {id}: full-cache snapshot does not match engine geometry"
                    ));
                }
            }
        }
        let mut indexes = Vec::with_capacity(snap.indexes.len());
        let mut buffers = Vec::with_capacity(snap.indexes.len());
        if self.mode == AttnMode::Wave {
            for (slot_i, bytes) in snap.indexes.iter().enumerate() {
                let idx = loop {
                    match WaveIndex::import_state(
                        &self.arena,
                        tenant,
                        self.zcfg.clone(),
                        bytes,
                    ) {
                        Ok(mut idx) => {
                            if let Some(p) = &self.spill_policy {
                                idx.set_spill_policy(Some(Arc::clone(p)));
                            }
                            idx.set_lossy_cos_floor(self.lossy_cos_floor);
                            break idx;
                        }
                        Err(e) => {
                            // mirror prefill: a full hot tier on a tiered
                            // arena means demote-then-retry, not refusal
                            let retry = matches!(
                                e,
                                SnapshotError::Alloc(AllocError::ArenaFull { .. })
                            ) && self.spill_enabled()
                                && self.make_room(64) > 0;
                            if !retry {
                                // `indexes`/`buffers` drop here: the
                                // partial import's blocks all return
                                self.metrics.inc("import_failures", 1);
                                self.publish_arena_gauges();
                                return Err(anyhow!(
                                    "import {id} (tenant {tenant}) slot {slot_i}: {e}"
                                ));
                            }
                        }
                    }
                };
                let tpb = idx.store().tokens_per_block();
                let cap = WaveBuffer::capacity_for(&self.bcfg, snap.len, tpb);
                let mut buf =
                    WaveBuffer::new(self.bcfg.clone(), d, tpb, cap, Arc::clone(&self.pool));
                if self.prefix.is_some() {
                    if self.shared_caches.len() <= slot_i {
                        let atpb = self.arena.tokens_per_block();
                        self.shared_caches.push(Arc::new(SharedBlockCache::new(
                            self.bcfg.policy,
                            self.shared_slot_capacity(),
                            2 * atpb * d,
                        )));
                    }
                    buf.set_shared_cache(Arc::clone(&self.shared_caches[slot_i]));
                }
                buf.register_index(&idx);
                indexes.push(idx);
                buffers.push(buf);
            }
        }
        self.states.insert(
            id,
            SessionState {
                indexes,
                buffers,
                k_full: snap.k_full.clone(),
                v_full: snap.v_full.clone(),
                len: snap.len,
                last_token: snap.last_token,
            },
        );
        self.metrics.inc("sessions_imported", 1);
        self.metrics.inc("migration_bytes_in", snap.payload_bytes() as u64);
        self.publish_arena_gauges();
        Ok(())
    }

    /// Preempt a live session to the cold tier mid-generation
    /// (DESIGN.md §2 "Online serving & preemption"): snapshot it
    /// through the bit-exact migration stream, park the snapshot off
    /// the arena, and free every hot block it held — the scheduler's
    /// lever for reclaiming capacity for SLO-critical tenants under
    /// pressure. Returns the number of blocks freed. A later
    /// [`LiveEngine::resume_session`] rebuilds it bit-identically: the
    /// snapshot captures everything token-bit-relevant (including the
    /// pending next token), so the resumed session's remaining tokens
    /// match an unpreempted run exactly.
    pub fn preempt_session(&mut self, id: u64) -> Result<usize> {
        let snap = self
            .export_session(id)
            .ok_or_else(|| anyhow!("preempt {id}: unknown session"))?;
        let freed = self.finish_session(id);
        self.metrics.inc("sessions_preempted", 1);
        self.metrics.inc("preempt_parked_bytes", snap.payload_bytes() as u64);
        self.parked.insert(id, snap);
        self.metrics.set_gauge("sessions_parked", self.parked.len() as u64);
        Ok(freed)
    }

    /// Bring a preempted session back onto the hot tier. On an import
    /// failure (e.g. the arena is still full and nothing is demotable)
    /// the snapshot goes back to the parked set, so the session stays
    /// resumable — nothing is lost.
    pub fn resume_session(&mut self, id: u64, tenant: TenantId) -> Result<()> {
        let snap = self
            .parked
            .remove(&id)
            .ok_or_else(|| anyhow!("resume {id}: session is not parked"))?;
        match self.import_session(id, tenant, &snap) {
            Ok(()) => {
                self.metrics.inc("sessions_resumed", 1);
                self.metrics.set_gauge("sessions_parked", self.parked.len() as u64);
                Ok(())
            }
            Err(e) => {
                self.parked.insert(id, snap);
                Err(e)
            }
        }
    }

    /// Whether `id` is currently parked (preempted, awaiting resume).
    pub fn is_parked(&self, id: u64) -> bool {
        self.parked.contains_key(&id)
    }

    /// Parked session ids (unordered).
    pub fn parked_ids(&self) -> Vec<u64> {
        self.parked.keys().copied().collect()
    }

    /// Total cold-parked snapshot bytes across preempted sessions.
    pub fn parked_bytes(&self) -> usize {
        self.parked.values().map(|s| s.payload_bytes()).sum()
    }
}

/// A session's serialized live state ([`LiveEngine::export_session`]):
/// everything token-bit-relevant — per-slot wave-index snapshot streams
/// (clusters through the spill page format, centroids/vsums/positions,
/// clustering seed), the context length, and the pending next token.
/// Full-attention sessions carry their padded caches instead.
#[derive(Clone, Debug)]
pub struct SessionSnapshot {
    /// Context length (prompt + generated so far).
    pub len: usize,
    /// The token the next decode step will consume.
    pub last_token: i32,
    /// Per-(layer, kv-head) wave-index snapshot streams (Wave mode).
    pub indexes: Vec<Vec<u8>>,
    /// Per-layer padded `[KVH, T, d]` caches (Full mode).
    pub k_full: Vec<Vec<f32>>,
    pub v_full: Vec<Vec<f32>>,
}

impl SessionSnapshot {
    /// Bytes this snapshot moves across the migration channel.
    pub fn payload_bytes(&self) -> usize {
        self.indexes.iter().map(|b| b.len()).sum::<usize>()
            + self
                .k_full
                .iter()
                .chain(self.v_full.iter())
                .map(|v| v.len() * 4)
                .sum::<usize>()
    }
}

/// Per-slot [`SharedBlockCache`] capacity (in blocks) under an
/// engine-level byte budget split evenly across `slots` (layer,
/// kv-head) slots; a cached block stores K and V halves as f32. Always
/// at least 1 so an armed cache is never a no-op.
pub fn shared_slot_capacity_for(budget_bytes: usize, slots: usize, tpb: usize, d: usize) -> usize {
    let block_bytes = 2 * tpb * d * 4;
    (budget_bytes / slots.max(1) / block_bytes.max(1)).max(1)
}

/// Region-structured synthetic prompt: each 256-token region draws from
/// its own 16-symbol alphabet, giving the topical locality of real text
/// (used by tests, examples and benches).
pub fn structured_prompt(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        if i % 256 == 0 {
            // new region: pick a fresh alphabet offset
            let base = rng.below(240);
            out.push(base as i32); // region marker token
            continue;
        }
        let region_base = (out[i - (i % 256)] as usize).min(239);
        out.push((region_base + rng.below(16)) as i32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifacts_dir;

    /// Region-structured prompt: each 256-token region draws from its own
    /// 16-symbol alphabet — the synthetic analog of topical text (uniform
    /// random tokens have no structure for ANY retrieval index to exploit).
    fn prompt(n: usize, seed: u64) -> Vec<i32> {
        structured_prompt(n, seed)
    }

    #[test]
    fn shared_cache_budget_sizing_is_even_and_nonzero() {
        // 1 MiB over 8 slots, 2 KB cached blocks (tpb 8, d 32, f32)
        assert_eq!(shared_slot_capacity_for(1 << 20, 8, 8, 32), 64);
        // a budget smaller than one block still arms the cache
        assert_eq!(shared_slot_capacity_for(100, 8, 8, 32), 1);
        // degenerate slot count is guarded, not a divide-by-zero
        assert_eq!(shared_slot_capacity_for(1 << 20, 0, 8, 32), 512);
    }

    #[test]
    fn wave_and_full_agree_on_greedy_tokens() {
        crate::require_live_path!();
        // The headline live-path test: RetroInfer's sparse decode must
        // reproduce full attention's greedy decode on a real prompt.
        let dir = default_artifacts_dir();
        let p = prompt(2048, 1);
        let mut full = LiveEngine::new(&dir, AttnMode::Full).unwrap();
        let mut wave = LiveEngine::new(&dir, AttnMode::Wave).unwrap();
        let f0 = full.prefill(1, &p).unwrap();
        let w0 = wave.prefill(1, &p).unwrap();
        assert_eq!(f0, w0, "first token must match");
        // Teacher-forced comparison: free-running sequences diverge
        // permanently after any single greedy flip, so force both engines
        // through the SAME token history and compare each step's
        // prediction (the stable fidelity metric).
        let mut same = 0;
        let steps = 8;
        let mut history = f0;
        for _ in 0..steps {
            full.force_token(1, history);
            wave.force_token(1, history);
            let ft = full.decode_step(&[1], 1).unwrap()[0];
            let wt = wave.decode_step(&[1], 1).unwrap()[0];
            if ft == wt {
                same += 1;
            }
            history = ft;
        }
        assert!(
            same * 2 >= steps,
            "wave decode diverged: {same}/{steps} predictions matched"
        );
    }

    #[test]
    fn batched_decode_consistent_with_single() {
        crate::require_live_path!();
        let dir = default_artifacts_dir();
        let p1 = prompt(2048, 2);
        let p2 = prompt(2048, 3);
        let mut eng = LiveEngine::new(&dir, AttnMode::Wave).unwrap();
        let mut solo = LiveEngine::new(&dir, AttnMode::Wave).unwrap();
        eng.prefill(1, &p1).unwrap();
        eng.prefill(2, &p2).unwrap();
        solo.prefill(1, &p1).unwrap();
        let batch = eng.decode_step(&[1, 2], 2).unwrap();
        let single = solo.decode_step(&[1], 1).unwrap();
        assert_eq!(batch[0], single[0], "batching must not change results");
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn padded_bucket_rows_are_discarded() {
        crate::require_live_path!();
        let dir = default_artifacts_dir();
        let p = prompt(2048, 4);
        let mut eng = LiveEngine::new(&dir, AttnMode::Wave).unwrap();
        eng.prefill(9, &p).unwrap();
        // 1 live session decoded at bucket 2
        let out = eng.decode_step(&[9], 2).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(eng.session_len(9), Some(2049));
    }

    #[test]
    fn rejects_unknown_session() {
        crate::require_live_path!();
        let dir = default_artifacts_dir();
        let mut eng = LiveEngine::new(&dir, AttnMode::Wave).unwrap();
        assert!(eng.decode_step(&[42], 1).is_err());
    }

    #[test]
    fn shared_prefix_prefill_dedups_and_decodes_identically() {
        crate::require_live_path!();
        let dir = default_artifacts_dir();
        // smaller build segments so a 2048-token prompt has several
        // sealable chain links
        let zcfg = ZoneConfig {
            retrieval_frac: 0.5,
            estimation_frac: 1.0,
            build_segment: 512,
            update_segment: 256,
            ..ZoneConfig::default()
        };
        let bcfg = BufferConfig { cache_frac: 0.25, ..BufferConfig::default() };
        let prefix = prompt(1792, 11);
        let mk_prompt = |i: u64| {
            let mut p = prefix.clone();
            p.extend_from_slice(&prompt(256, 100 + i));
            p
        };
        // unshared baseline with content seeds: bit-comparable clustering
        let mut base = LiveEngine::with_config(&dir, AttnMode::Wave, zcfg.clone(), bcfg.clone())
            .unwrap();
        base.set_content_seeds(true);
        let mut shared =
            LiveEngine::with_config(&dir, AttnMode::Wave, zcfg, bcfg).unwrap();
        shared.enable_prefix_sharing(8);
        let n = 3u64;
        for i in 0..n {
            let p = mk_prompt(i);
            let t_base = base.prefill(i, &p).unwrap();
            let t_shared = shared.prefill(i, &p).unwrap();
            assert_eq!(t_base, t_shared, "session {i}: grafted prefill changed the first token");
        }
        assert_eq!(shared.metrics.counter("prefix_hits"), n - 1);
        assert!(shared.metrics.counter("prefix_matched_tokens") > 0);
        assert!(shared.arena().shared_blocks_live() > 0);
        // the shared arena holds ~one copy of the prefix; the baseline N
        assert!(
            shared.arena().live_blocks() < base.arena().live_blocks(),
            "sharing must shrink the resident footprint ({} vs {})",
            shared.arena().live_blocks(),
            base.arena().live_blocks()
        );
        let refs = shared.arena().shared_session_refs();
        let blocks = shared.arena().shared_blocks_live();
        assert!(
            refs >= (n as usize) * blocks,
            "every live session must reference the shared prefix ({refs} refs, {blocks} blocks)"
        );
        // decode stays bit-identical to the unshared run
        let ids: Vec<u64> = (0..n).collect();
        for _ in 0..4 {
            let tb = base.decode_step(&ids, 4).unwrap();
            let ts = shared.decode_step(&ids, 4).unwrap();
            assert_eq!(tb, ts, "shared-prefix decode diverged");
        }
        // teardown: sessions exit, the registry still pins the prefix
        for i in 0..n {
            shared.finish_session(i);
        }
        assert!(shared.arena().live_blocks() > 0, "registry keeps the prefix resident");
        shared.clear_prefix_cache();
        assert_eq!(shared.arena().live_blocks(), 0, "cleared prefix frees at refcount zero");
    }

    #[test]
    fn migrated_session_resumes_bit_identically() {
        crate::require_live_path!();
        let dir = default_artifacts_dir();
        let p = prompt(2048, 21);
        // a: uninterrupted reference run; b: source replica; c: target
        let mut a = LiveEngine::new(&dir, AttnMode::Wave).unwrap();
        let mut b = LiveEngine::new(&dir, AttnMode::Wave).unwrap();
        let mut c = LiveEngine::new(&dir, AttnMode::Wave).unwrap();
        let t0a = a.prefill(1, &p).unwrap();
        let t0b = b.prefill(1, &p).unwrap();
        assert_eq!(t0a, t0b, "identical prefills must agree");
        for _ in 0..3 {
            let ta = a.decode_step(&[1], 1).unwrap()[0];
            let tb = b.decode_step(&[1], 1).unwrap()[0];
            assert_eq!(ta, tb, "pre-migration decode diverged");
        }
        // migrate b's session to c mid-generation
        let snap = b.export_session(1).expect("live session exports");
        assert!(snap.payload_bytes() > 0);
        assert_eq!(b.export_session(99).map(|s| s.len), None, "unknown id");
        b.finish_session(1);
        assert_eq!(b.arena().live_blocks(), 0, "source released every block");
        c.import_session(1, DEFAULT_TENANT, &snap).unwrap();
        assert_eq!(c.session_len(1), Some(2051));
        assert!(c.arena().live_blocks() > 0);
        // the migrated session's remaining tokens are bit-identical to
        // the unmigrated run — the tentpole's headline invariant
        for step in 0..5 {
            let ta = a.decode_step(&[1], 1).unwrap()[0];
            let tc = c.decode_step(&[1], 1).unwrap()[0];
            assert_eq!(ta, tc, "migrated session diverged at step {step}");
        }
        // a second import of the same id must refuse, not clobber
        assert!(c.import_session(1, DEFAULT_TENANT, &snap).is_err());
        // geometry mismatch refuses and leaks nothing
        let mut bad = snap.clone();
        bad.indexes.pop();
        let before = b.arena().live_blocks();
        assert!(b.import_session(2, DEFAULT_TENANT, &bad).is_err());
        assert_eq!(b.arena().live_blocks(), before, "failed import must roll back");
        c.finish_session(1);
        assert_eq!(c.arena().live_blocks(), 0);
    }

    #[test]
    fn capped_arena_prefill_fails_gracefully_and_leaks_nothing() {
        crate::require_live_path!();
        let dir = default_artifacts_dir();
        let p = prompt(2048, 6);
        let mut eng = LiveEngine::new(&dir, AttnMode::Wave).unwrap();
        eng.set_arena_capacity_blocks(Some(8));
        assert!(eng.prefill_for(1, 3, &p).is_err(), "capped prefill must refuse, not panic");
        assert_eq!(eng.arena().live_blocks(), 0, "failed prefill must return every block");
        assert_eq!(eng.arena().tenant_live_blocks(3), 0);
        assert_eq!(eng.metrics.counter("prefill_alloc_failures"), 1);
        // lifting the cap lets the same request serve
        eng.set_arena_capacity_blocks(None);
        assert!(eng.prefill_for(1, 3, &p).is_ok());
        assert!(eng.arena().live_blocks() > 0);
        assert!(eng.arena().tenant_live_blocks(3) > 0);
        eng.finish_session(1);
        assert_eq!(eng.arena().tenant_live_blocks(3), 0);
    }

    #[test]
    fn chunked_prefill_matches_monolithic_bit_identically() {
        crate::require_live_path!();
        let dir = default_artifacts_dir();
        // smaller build segments so the chunk boundaries cross several
        // re-cluster boundaries inside a 2048-token prompt
        let zcfg = ZoneConfig {
            retrieval_frac: 0.5,
            estimation_frac: 1.0,
            build_segment: 512,
            update_segment: 256,
            ..ZoneConfig::default()
        };
        let bcfg = BufferConfig { cache_frac: 0.25, ..BufferConfig::default() };
        let p = prompt(2048, 31);
        let mut mono =
            LiveEngine::with_config(&dir, AttnMode::Wave, zcfg.clone(), bcfg.clone()).unwrap();
        let t_mono = mono.prefill(1, &p).unwrap();
        let snap_mono = mono.export_session(1).unwrap();
        // chunk sizes straddling the segment size (512): mid-segment,
        // exactly one segment, off-by-one around it, and sub-cluster
        for &cs in &[113usize, 511, 512, 513, 2048] {
            let mut eng =
                LiveEngine::with_config(&dir, AttnMode::Wave, zcfg.clone(), bcfg.clone())
                    .unwrap();
            let mut job = eng.prefill_start(1, DEFAULT_TENANT, &p).unwrap();
            let mut chunks = 0;
            while !eng.prefill_advance(&mut job, cs).unwrap() {
                chunks += 1;
                assert!(job.fed_tokens() < job.total_tokens());
            }
            assert!(job.done());
            assert_eq!(chunks + 1, p.len().div_ceil(cs), "chunk count for size {cs}");
            let t_chunked = eng.prefill_finish(job).unwrap();
            assert_eq!(t_chunked, t_mono, "chunk size {cs}: first token diverged");
            // full index state (clusters through the spill page format,
            // centroids, vsums, positions, seed) must match byte-for-byte
            let snap = eng.export_session(1).unwrap();
            assert_eq!(
                snap.indexes, snap_mono.indexes,
                "chunk size {cs}: index snapshot diverged from monolithic"
            );
            // and decode stays bit-identical
            for step in 0..3 {
                let tm = mono.decode_step(&[1], 1).unwrap()[0];
                let tc = eng.decode_step(&[1], 1).unwrap()[0];
                assert_eq!(tm, tc, "chunk size {cs}: decode diverged at step {step}");
            }
            // re-sync the monolithic reference for the next chunk size
            mono.finish_session(1);
            mono.import_session(1, DEFAULT_TENANT, &snap_mono).unwrap();
        }
    }

    #[test]
    fn unfinished_prefill_job_refuses_finish_and_drop_leaks_nothing() {
        crate::require_live_path!();
        let dir = default_artifacts_dir();
        let p = prompt(2048, 32);
        let mut eng = LiveEngine::new(&dir, AttnMode::Wave).unwrap();
        let mut job = eng.prefill_start(1, DEFAULT_TENANT, &p).unwrap();
        assert!(!eng.prefill_advance(&mut job, 256).unwrap());
        assert_eq!(job.fed_tokens(), 256);
        assert!(eng.prefill_finish(job).is_err(), "finish before all chunks must refuse");
        // the job dropped inside prefill_finish's error path: every
        // checked-out block is back
        assert_eq!(eng.arena().live_blocks(), 0, "aborted job must return every block");
        assert_eq!(eng.n_sessions(), 0);
    }

    #[test]
    fn preempted_session_resumes_bit_identically() {
        crate::require_live_path!();
        let dir = default_artifacts_dir();
        let p1 = prompt(2048, 41);
        let p2 = prompt(2048, 42);
        // a: uninterrupted reference run of session 1
        let mut a = LiveEngine::new(&dir, AttnMode::Wave).unwrap();
        let mut b = LiveEngine::new(&dir, AttnMode::Wave).unwrap();
        let t0a = a.prefill(1, &p1).unwrap();
        let t0b = b.prefill(1, &p1).unwrap();
        assert_eq!(t0a, t0b);
        b.prefill(2, &p2).unwrap();
        for _ in 0..3 {
            let ta = a.decode_step(&[1], 1).unwrap()[0];
            let tb = b.decode_step(&[1], 1).unwrap()[0];
            assert_eq!(ta, tb, "pre-preemption decode diverged");
        }
        // preempt session 1 mid-generation: its hot blocks free, the
        // snapshot parks cold
        let live_before = b.arena().live_blocks();
        let freed = b.preempt_session(1).unwrap();
        assert!(freed > 0, "preemption must free hot blocks");
        assert_eq!(b.arena().live_blocks(), live_before - freed);
        assert!(b.is_parked(1));
        assert!(b.parked_bytes() > 0);
        assert_eq!(b.session_len(1), None, "preempted session is not live");
        assert!(b.preempt_session(1).is_err(), "parked session cannot preempt again");
        // the survivor keeps decoding while 1 is parked (the churn the
        // scheduler creates when it reclaims capacity under pressure)
        for _ in 0..4 {
            b.decode_step(&[2], 1).unwrap();
        }
        // resume and verify the remaining tokens match the unpreempted run
        b.resume_session(1, DEFAULT_TENANT).unwrap();
        assert!(!b.is_parked(1));
        assert_eq!(b.parked_bytes(), 0);
        for step in 0..5 {
            let ta = a.decode_step(&[1], 1).unwrap()[0];
            let tb = b.decode_step(&[1], 1).unwrap()[0];
            assert_eq!(ta, tb, "resumed session diverged at step {step}");
        }
        assert!(b.resume_session(7, DEFAULT_TENANT).is_err(), "unknown id cannot resume");
    }
}

#[cfg(test)]
mod fidelity_tests {
    use super::*;
    use crate::attention::full_attention;
    use crate::runtime::default_artifacts_dir;
    use crate::util::stats::cosine;

    /// Reconstruct full attention from the wave index's own storage and
    /// compare against the engine's tripartite kernel output, per head.
    #[test]
    fn wave_ctx_tracks_exact_ctx() {
        crate::require_live_path!();
        let dir = default_artifacts_dir();
        let p = crate::engine::live::structured_prompt(2048, 5);
        let mut eng = LiveEngine::new(&dir, AttnMode::Wave).unwrap();
        eng.prefill(1, &p).unwrap();

        // one decode step, but instrumented: recompute qkv and compare
        let st = &eng.states[&1];
        let tokens = vec![st.last_token];
        let pos = vec![st.len as i32];
        let hidden = eng.lm.embed(&tokens).unwrap();
        let (kvh, d) = (eng.lm.cfg.kv_heads, eng.lm.cfg.d_head);
        let group = eng.lm.cfg.group();
        let (ne, m_cap) = (eng.lm.buckets.wave_ne, eng.lm.buckets.wave_m);

        let layer = 0;
        let (q, k, v) = eng.lm.qkv(layer, &hidden, &pos).unwrap();
        for (i, id) in [1u64].iter().enumerate() {
            let stm = eng.states.get_mut(id).unwrap();
            for h in 0..kvh {
                stm.indexes[layer * kvh + h].append(k.row(&[i, h]), v.row(&[i, h]));
            }
        }
        let mut wi = WaveInputs::zeros(1, kvh, ne, m_cap, d);
        for h in 0..kvh {
            eng.assemble_head(1, layer, h, 0, &q, &mut wi).unwrap();
        }
        let ctx = eng.lm.attn_wave(&q, &wi).unwrap(); // [1, q_dim]

        // exact reference from the index's full KV
        for h in 0..kvh {
            let st = &eng.states[&1];
            let idx = &st.indexes[layer * kvh + h];
            // gather every token (clusters + steady)
            let mut keys = Vec::new();
            let mut vals = Vec::new();
            for c in 0..idx.meta().m() {
                for r in idx.cluster_blocks(c as u32) {
                    keys.extend_from_slice(idx.store().block_keys(*r));
                    vals.extend_from_slice(idx.store().block_vals(*r));
                }
            }
            let (sk, sv) = idx.steady_kv();
            keys.extend_from_slice(&sk);
            vals.extend_from_slice(&sv);
            for g in 0..group {
                let qr = q.row(&[0, h, g]);
                let mut exact = vec![0.0f32; d];
                full_attention(qr, &keys, &vals, d, &mut exact);
                let got = &ctx.data()[(h * group + g) * d..(h * group + g + 1) * d];
                let c = cosine(got, &exact);
                // rust-side tripartite with the same selection, for triage
                let mut sc = SelectScratch::default();
                let mut qg = vec![0.0f32; group * d];
                for gg in 0..group {
                    qg[gg * d..(gg + 1) * d].copy_from_slice(q.row(&[0, h, gg]));
                }
                let m = idx.meta().m();
                let r = idx.cfg().retrieval_clusters(m).max(2 * group).min(m);
                let e = idx.cfg().estimation_clusters(m).min(m.saturating_sub(r));
                let sel = idx.select_group_with(&qg, group, r, e, &mut sc);
                let mut rust_out = vec![0.0f32; d];
                idx.attend(qr, &sel, &mut rust_out);
                let c_rust = cosine(&rust_out, &exact);
                // kernel path and pure-Rust path agree bit-for-bit on the
                // same selection; the NE-capacity trim makes the kernel's
                // effective budget slightly smaller, so assert both.
                assert!(c_rust > 0.9, "head {h} group {g}: rust/exact = {c_rust:.4}");
                assert!(c > 0.85, "head {h} group {g}: kernel/exact = {c:.4}");
            }
        }
    }
}
