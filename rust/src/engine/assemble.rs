//! Per-head execution-buffer assembly and its batch fan-out.
//!
//! One decode step needs `batch × kv_heads` independent assemblies per
//! layer: zone selection over the head's wave index, execution-buffer
//! gather through the head's wave buffer, and estimation-zone meta
//! packing. Each assembly reads one session's (index, buffer) pair and
//! writes one disjoint `(row, head)` slice of the kernel's
//! [`WaveInputs`], so the batch fans out across the engine
//! [`ThreadPool`] with no synchronization beyond the buffer's own
//! internal locks ([`BatchAssembler::assemble_into`]). The sequential
//! path runs the exact same code in a loop — outputs are bit-identical
//! either way (asserted by `tests/arena.rs`), only wall-clock differs.

use crate::buffer::{AccessStats, ExecBuffer, WaveBuffer};
use crate::index::{SelectScratch, WaveIndex};
use crate::runtime::tinylm::WaveInputs;
use crate::util::threadpool::ThreadPool;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Geometry of one assembly: execution-buffer capacity, estimation-slot
/// capacity, head dim and GQA group size.
#[derive(Clone, Copy, Debug)]
pub struct AssembleShape {
    pub ne: usize,
    pub m_cap: usize,
    pub d: usize,
    pub group: usize,
}

/// One (row, head) unit of work: the session's per-head index + buffer.
#[derive(Clone, Copy)]
pub struct HeadTask<'a> {
    pub index: &'a WaveIndex,
    pub buffer: &'a WaveBuffer,
}

/// The `(row, head)` slice of [`WaveInputs`] one assembly writes.
pub struct HeadSlices<'a> {
    pub kx: &'a mut [f32],
    pub vx: &'a mut [f32],
    pub kmask: &'a mut [f32],
    pub cent: &'a mut [f32],
    pub vsum: &'a mut [f32],
    pub csize: &'a mut [f32],
    pub emask: &'a mut [f32],
}

/// Assemble one (sequence, head) slice of the wave-attention inputs:
/// zone selection, execution-buffer gather through the wave buffer, and
/// estimation-zone meta arrays. `qg` is the `[group, d]` flat query
/// group sharing this KV head. Slices are fully overwritten (zeroed
/// first), so callers may reuse a dirty [`WaveInputs`] across layers
/// and steps.
pub fn assemble_head(
    task: HeadTask<'_>,
    qg: &[f32],
    shape: AssembleShape,
    scratch: &mut SelectScratch,
    eb: &mut ExecBuffer,
    out: &mut HeadSlices<'_>,
) -> AccessStats {
    let AssembleShape { ne, m_cap, d, group } = shape;
    debug_assert_eq!(qg.len(), group * d);
    out.kx.fill(0.0);
    out.vx.fill(0.0);
    out.kmask.fill(0.0);
    out.cent.fill(0.0);
    out.vsum.fill(0.0);
    out.csize.fill(0.0);
    out.emask.fill(0.0);

    let index = task.index;
    let m = index.meta().m();
    // Budgets from the zone config, floored at 2 clusters per group
    // query head (short contexts under-provision fractional budgets).
    let r = index.cfg().retrieval_clusters(m).max(2 * group).min(m);
    let e = index.cfg().estimation_clusters(m).min(m.saturating_sub(r));
    let t_select = Instant::now();
    let sel = index.select_group_into(qg, group, r, e, scratch);
    // Trim retrieval in place so steady + retrieved tokens fit the Ne
    // buffer (write-index compaction: no allocation, order preserved).
    let mut budget = ne.saturating_sub(index.steady_tokens());
    let mut w = 0;
    for i in 0..sel.retrieval.len() {
        let c = sel.retrieval[i];
        let sz = index.meta().cluster_tokens(c as usize).len();
        if sz <= budget {
            budget -= sz;
            sel.retrieval[w] = c;
            w += 1;
        }
    }
    sel.retrieval.truncate(w);
    sel.estimation.truncate(m_cap);

    // Record the selection for the spill machinery: access epochs feed
    // the demotion policy, and the wanted set (retrieval + estimation)
    // is what the engine prefetches from the cold tier for the next
    // step — the estimation zone is the estimator's shortlist of what
    // retrieval will want as the query drifts.
    index.note_selection(sel);
    let select_ns = t_select.elapsed().as_nanos() as u64;

    // Execution buffer via the wave buffer (steady + hits + misses +
    // cold-hit stalls).
    let t_gather = Instant::now();
    let mut stats = task.buffer.assemble(index, sel, eb);

    let n_tok = eb.n_tokens().min(ne);
    out.kx[..n_tok * d].copy_from_slice(&eb.keys[..n_tok * d]);
    out.vx[..n_tok * d].copy_from_slice(&eb.vals[..n_tok * d]);
    out.kmask[..n_tok].fill(1.0);

    // Estimation zone: pack selected clusters densely into the M slots.
    for (s, &c) in sel.estimation.iter().enumerate() {
        let c = c as usize;
        out.cent[s * d..(s + 1) * d].copy_from_slice(index.meta().centroid(c));
        out.vsum[s * d..(s + 1) * d]
            .copy_from_slice(&index.meta().vsum_flat()[c * d..(c + 1) * d]);
        out.csize[s] = index.meta().counts()[c];
        out.emask[s] = 1.0;
    }
    stats.select_ns = select_ns;
    stats.gather_ns = t_gather.elapsed().as_nanos() as u64;
    stats
}

/// Raw base pointers of a [`WaveInputs`], sendable across the pool so
/// each task can carve out its own disjoint `(row, head)` slice.
struct WavePtrs {
    kx: *mut f32,
    vx: *mut f32,
    kmask: *mut f32,
    cent: *mut f32,
    vsum: *mut f32,
    csize: *mut f32,
    emask: *mut f32,
}

// SAFETY: the pointers are only dereferenced through `slices`, which
// hands every task index a disjoint region; `assemble_into` holds the
// `&mut WaveInputs` borrow for the whole scope.
unsafe impl Send for WavePtrs {}
unsafe impl Sync for WavePtrs {}

impl WavePtrs {
    fn of(wi: &mut WaveInputs) -> WavePtrs {
        WavePtrs {
            kx: wi.kx.as_mut_ptr(),
            vx: wi.vx.as_mut_ptr(),
            kmask: wi.kmask.as_mut_ptr(),
            cent: wi.cent.as_mut_ptr(),
            vsum: wi.vsum.as_mut_ptr(),
            csize: wi.csize.as_mut_ptr(),
            emask: wi.emask.as_mut_ptr(),
        }
    }

    /// The `(row, head)` slice set of flat task `t`.
    ///
    /// SAFETY: caller must ensure distinct `t` for concurrent calls and
    /// that the backing `WaveInputs` outlives the returned slices and
    /// holds at least `(t + 1)` head segments.
    unsafe fn slices<'a>(&self, t: usize, shape: AssembleShape) -> HeadSlices<'a> {
        let AssembleShape { ne, m_cap, d, .. } = shape;
        /// SAFETY: see [`WavePtrs::slices`] — disjoint `t`, live backing.
        unsafe fn seg<'b>(p: *mut f32, t: usize, stride: usize) -> &'b mut [f32] {
            unsafe { std::slice::from_raw_parts_mut(p.add(t * stride), stride) }
        }
        unsafe {
            HeadSlices {
                kx: seg(self.kx, t, ne * d),
                vx: seg(self.vx, t, ne * d),
                kmask: seg(self.kmask, t, ne),
                cent: seg(self.cent, t, m_cap * d),
                vsum: seg(self.vsum, t, m_cap * d),
                csize: seg(self.csize, t, m_cap),
                emask: seg(self.emask, t, m_cap),
            }
        }
    }
}

/// The recycled per-task state of one `(row, head)` assembly slot:
/// select scratch, execution buffer, and the slot's last stats (read
/// back by `assemble_into` after the scope joins, so the hot path never
/// touches a shared aggregate lock).
#[derive(Default)]
struct TaskSlot {
    scratch: SelectScratch,
    eb: ExecBuffer,
    stats: AccessStats,
}

/// Batch assembler: fans the per-(row, head) assemblies of one decode
/// step across the engine thread pool. Each flat task index owns a
/// dedicated [`TaskSlot`] (scratch + exec buffer + stats), so steady-
/// state decode touches no contended lock and performs no allocation:
/// the `RwLock` is only write-locked to grow the slot vector when the
/// batch widens, and each slot's `Mutex` is uncontended by construction
/// (one task per slot).
pub struct BatchAssembler {
    pool: Arc<ThreadPool>,
    parallel: bool,
    slots: RwLock<Vec<Mutex<TaskSlot>>>,
}

impl BatchAssembler {
    pub fn new(pool: Arc<ThreadPool>, parallel: bool) -> BatchAssembler {
        BatchAssembler { pool, parallel, slots: RwLock::new(Vec::new()) }
    }

    pub fn parallel(&self) -> bool {
        self.parallel
    }

    pub fn set_parallel(&mut self, parallel: bool) {
        self.parallel = parallel;
    }

    /// Assemble every task's `(row, head)` slice of `wi`. `qg_all` is
    /// `[tasks, group, d]` flat. Returns the aggregate data-movement
    /// stats of the whole batch.
    pub fn assemble_into(
        &self,
        tasks: &[HeadTask<'_>],
        qg_all: &[f32],
        shape: AssembleShape,
        wi: &mut WaveInputs,
    ) -> AccessStats {
        let n = tasks.len();
        let gd = shape.group * shape.d;
        assert_eq!(qg_all.len(), n * gd, "qg_all shape mismatch");
        // Every field the raw-pointer slicing will carve must be large
        // enough — WaveInputs' fields are public, so an inconsistently
        // sized input must fail loudly here, not write out of bounds.
        let (ned, md) = (shape.ne * shape.d, shape.m_cap * shape.d);
        assert!(wi.kx.len() >= n * ned, "WaveInputs.kx too small for batch");
        assert!(wi.vx.len() >= n * ned, "WaveInputs.vx too small for batch");
        assert!(wi.kmask.len() >= n * shape.ne, "WaveInputs.kmask too small for batch");
        assert!(wi.cent.len() >= n * md, "WaveInputs.cent too small for batch");
        assert!(wi.vsum.len() >= n * md, "WaveInputs.vsum too small for batch");
        assert!(wi.csize.len() >= n * shape.m_cap, "WaveInputs.csize too small for batch");
        assert!(wi.emask.len() >= n * shape.m_cap, "WaveInputs.emask too small for batch");
        let ptrs = WavePtrs::of(wi);
        if self.slots.read().unwrap().len() < n {
            let mut slots = self.slots.write().unwrap();
            while slots.len() < n {
                slots.push(Mutex::new(TaskSlot::default()));
            }
        }
        let slots = self.slots.read().unwrap();
        let run = |t: usize| {
            // Uncontended by construction: flat task `t` is the only
            // user of slot `t` within this scope.
            let mut slot = slots[t].lock().unwrap();
            let slot = &mut *slot;
            if slot.eb.d() != shape.d {
                slot.eb = ExecBuffer::new(shape.d);
            }
            // SAFETY: task `t` is unique within this scope, and `wi` is
            // mutably borrowed by `assemble_into` for the scope's whole
            // lifetime — the slices are disjoint and live long enough.
            let mut out = unsafe { ptrs.slices(t, shape) };
            slot.stats = assemble_head(
                tasks[t],
                &qg_all[t * gd..(t + 1) * gd],
                shape,
                &mut slot.scratch,
                &mut slot.eb,
                &mut out,
            );
        };
        if self.parallel && n > 1 {
            self.pool.scope_for_each(n, &run);
        } else {
            for t in 0..n {
                run(t);
            }
        }
        let mut agg = AccessStats::default();
        for slot in slots.iter().take(n) {
            agg.add(&slot.lock().unwrap().stats);
        }
        agg
    }
}
