//! Per-head execution-buffer assembly and its batch fan-out.
//!
//! One decode step needs `batch × kv_heads` independent assemblies per
//! layer: zone selection over the head's wave index, execution-buffer
//! gather through the head's wave buffer, and estimation-zone meta
//! packing. Each assembly reads one session's (index, buffer) pair and
//! writes one disjoint `(row, head)` slice of the kernel's
//! [`WaveInputs`], so the batch fans out across the engine
//! [`ThreadPool`] with no synchronization beyond the buffer's own
//! internal locks ([`BatchAssembler::assemble_into`]). The sequential
//! path runs the exact same code in a loop — outputs are bit-identical
//! either way (asserted by `tests/arena.rs`), only wall-clock differs.

use crate::buffer::{AccessStats, ExecBuffer, WaveBuffer};
use crate::index::{SelectScratch, WaveIndex};
use crate::runtime::tinylm::WaveInputs;
use crate::util::threadpool::ThreadPool;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Instant;

/// Geometry of one assembly: execution-buffer capacity, estimation-slot
/// capacity, head dim and GQA group size.
#[derive(Clone, Copy, Debug)]
pub struct AssembleShape {
    pub ne: usize,
    pub m_cap: usize,
    pub d: usize,
    pub group: usize,
}

/// One (row, head) unit of work: the session's per-head index + buffer.
#[derive(Clone, Copy)]
pub struct HeadTask<'a> {
    pub index: &'a WaveIndex,
    pub buffer: &'a WaveBuffer,
}

/// The `(row, head)` slice of [`WaveInputs`] one assembly writes.
pub struct HeadSlices<'a> {
    pub kx: &'a mut [f32],
    pub vx: &'a mut [f32],
    pub kmask: &'a mut [f32],
    pub cent: &'a mut [f32],
    pub vsum: &'a mut [f32],
    pub csize: &'a mut [f32],
    pub emask: &'a mut [f32],
}

/// Stage 1 of one (row, head) assembly: zone selection (GQA-batched
/// centroid scoring), Ne-budget trim, and the selection note for the
/// spill machinery. The selection is left in `scratch` for
/// [`gather_head`]. Returns the select-phase nanoseconds.
pub fn select_head(
    task: HeadTask<'_>,
    qg: &[f32],
    shape: AssembleShape,
    scratch: &mut SelectScratch,
) -> u64 {
    let AssembleShape { ne, m_cap, d, group } = shape;
    debug_assert_eq!(qg.len(), group * d);
    let index = task.index;
    let m = index.meta().m();
    // Budgets from the zone config, floored at 2 clusters per group
    // query head (short contexts under-provision fractional budgets).
    let r = index.cfg().retrieval_clusters(m).max(2 * group).min(m);
    let e = index.cfg().estimation_clusters(m).min(m.saturating_sub(r));
    let t_select = Instant::now();
    let sel = index.select_group_into(qg, group, r, e, scratch);
    // Trim retrieval in place so steady + retrieved tokens fit the Ne
    // buffer (write-index compaction: no allocation, order preserved).
    let mut budget = ne.saturating_sub(index.steady_tokens());
    let mut w = 0;
    for i in 0..sel.retrieval.len() {
        let c = sel.retrieval[i];
        let sz = index.meta().cluster_tokens(c as usize).len();
        if sz <= budget {
            budget -= sz;
            sel.retrieval[w] = c;
            w += 1;
        }
    }
    sel.retrieval.truncate(w);
    sel.estimation.truncate(m_cap);

    // Record the selection for the spill machinery: access epochs feed
    // the demotion policy, and the wanted set (retrieval + estimation)
    // is what the engine prefetches from the cold tier for the next
    // step — the estimation zone is the estimator's shortlist of what
    // retrieval will want as the query drifts.
    index.note_selection(sel);
    t_select.elapsed().as_nanos() as u64
}

/// The engine-global ids of every spilled (non-hot) block the gather of
/// the selection in `scratch` will read, appended to `cold` (cleared
/// first, sorted, deduped). These are the pages the pipelined executor
/// issues as async I/O the moment selection completes.
pub fn cold_blocks_of(task: HeadTask<'_>, scratch: &SelectScratch, cold: &mut Vec<u64>) {
    cold.clear();
    let index = task.index;
    for &c in &scratch.selection().retrieval {
        for r in index.cluster_blocks(c) {
            if !index.store().is_hot(*r) {
                cold.push(r.block);
            }
        }
    }
    cold.sort_unstable();
    cold.dedup();
}

/// Stage 2: execution-buffer gather through the wave buffer plus
/// estimation-zone meta packing, for the selection [`select_head`] left
/// in `scratch`. Slices are fully overwritten (zeroed first), so
/// callers may reuse a dirty [`WaveInputs`] across layers and steps.
/// Sets `gather_ns`; the caller stamps `select_ns`.
pub fn gather_head(
    task: HeadTask<'_>,
    shape: AssembleShape,
    scratch: &SelectScratch,
    eb: &mut ExecBuffer,
    out: &mut HeadSlices<'_>,
) -> AccessStats {
    let AssembleShape { ne, d, .. } = shape;
    out.kx.fill(0.0);
    out.vx.fill(0.0);
    out.kmask.fill(0.0);
    out.cent.fill(0.0);
    out.vsum.fill(0.0);
    out.csize.fill(0.0);
    out.emask.fill(0.0);

    let index = task.index;
    let sel = scratch.selection();
    // Execution buffer via the wave buffer (steady + hits + misses +
    // cold-hit stalls or staged-page reads).
    let t_gather = Instant::now();
    let mut stats = task.buffer.assemble(index, sel, eb);

    let n_tok = eb.n_tokens().min(ne);
    out.kx[..n_tok * d].copy_from_slice(&eb.keys[..n_tok * d]);
    out.vx[..n_tok * d].copy_from_slice(&eb.vals[..n_tok * d]);
    out.kmask[..n_tok].fill(1.0);

    // Estimation zone: pack selected clusters densely into the M slots.
    for (s, &c) in sel.estimation.iter().enumerate() {
        let c = c as usize;
        out.cent[s * d..(s + 1) * d].copy_from_slice(index.meta().centroid(c));
        out.vsum[s * d..(s + 1) * d]
            .copy_from_slice(&index.meta().vsum_flat()[c * d..(c + 1) * d]);
        out.csize[s] = index.meta().counts()[c];
        out.emask[s] = 1.0;
    }
    stats.gather_ns = t_gather.elapsed().as_nanos() as u64;
    stats
}

/// Assemble one (sequence, head) slice of the wave-attention inputs:
/// zone selection, execution-buffer gather through the wave buffer, and
/// estimation-zone meta arrays. `qg` is the `[group, d]` flat query
/// group sharing this KV head. The sequential composition of
/// [`select_head`] + [`gather_head`] — the pipelined executor runs the
/// same two stages with async I/O between them, so the two paths are
/// bit-identical by construction.
pub fn assemble_head(
    task: HeadTask<'_>,
    qg: &[f32],
    shape: AssembleShape,
    scratch: &mut SelectScratch,
    eb: &mut ExecBuffer,
    out: &mut HeadSlices<'_>,
) -> AccessStats {
    let select_ns = select_head(task, qg, shape, scratch);
    let mut stats = gather_head(task, shape, scratch, eb, out);
    stats.select_ns = select_ns;
    stats
}

/// Raw base pointers of a [`WaveInputs`], sendable across the pool so
/// each task can carve out its own disjoint `(row, head)` slice.
struct WavePtrs {
    kx: *mut f32,
    vx: *mut f32,
    kmask: *mut f32,
    cent: *mut f32,
    vsum: *mut f32,
    csize: *mut f32,
    emask: *mut f32,
}

// SAFETY: the pointers are only dereferenced through `slices`, which
// hands every task index a disjoint region; `assemble_into` holds the
// `&mut WaveInputs` borrow for the whole scope.
unsafe impl Send for WavePtrs {}
unsafe impl Sync for WavePtrs {}

impl WavePtrs {
    fn of(wi: &mut WaveInputs) -> WavePtrs {
        WavePtrs {
            kx: wi.kx.as_mut_ptr(),
            vx: wi.vx.as_mut_ptr(),
            kmask: wi.kmask.as_mut_ptr(),
            cent: wi.cent.as_mut_ptr(),
            vsum: wi.vsum.as_mut_ptr(),
            csize: wi.csize.as_mut_ptr(),
            emask: wi.emask.as_mut_ptr(),
        }
    }

    /// The `(row, head)` slice set of flat task `t`.
    ///
    /// SAFETY: caller must ensure distinct `t` for concurrent calls and
    /// that the backing `WaveInputs` outlives the returned slices and
    /// holds at least `(t + 1)` head segments.
    unsafe fn slices<'a>(&self, t: usize, shape: AssembleShape) -> HeadSlices<'a> {
        let AssembleShape { ne, m_cap, d, .. } = shape;
        /// SAFETY: see [`WavePtrs::slices`] — disjoint `t`, live backing.
        unsafe fn seg<'b>(p: *mut f32, t: usize, stride: usize) -> &'b mut [f32] {
            unsafe { std::slice::from_raw_parts_mut(p.add(t * stride), stride) }
        }
        unsafe {
            HeadSlices {
                kx: seg(self.kx, t, ne * d),
                vx: seg(self.vx, t, ne * d),
                kmask: seg(self.kmask, t, ne),
                cent: seg(self.cent, t, m_cap * d),
                vsum: seg(self.vsum, t, m_cap * d),
                csize: seg(self.csize, t, m_cap),
                emask: seg(self.emask, t, m_cap),
            }
        }
    }
}

/// The recycled per-task state of one `(row, head)` assembly slot:
/// select scratch, execution buffer, and the slot's last stats (read
/// back by `assemble_into` after the scope joins, so the hot path never
/// touches a shared aggregate lock).
#[derive(Default)]
struct TaskSlot {
    scratch: SelectScratch,
    eb: ExecBuffer,
    stats: AccessStats,
    /// Select-phase nanoseconds of the pipelined split (stamped onto
    /// `stats` after the gather stage runs).
    select_ns: u64,
    /// Cold-page worklist of the pipelined split (reused across steps).
    cold: Vec<u64>,
}

/// Cross-thread rendezvous of the pipelined executor. I/O-lane jobs
/// decrement a task's outstanding-page count and push the task onto the
/// ready queue when its last page lands; compute-lane drain jobs pop
/// tasks in completion order. Persistent (`Arc`, capacity retained
/// across steps) because `ThreadPool::submit_io` closures must be
/// `'static` — and so the steady-state pipelined step allocates nothing
/// here.
#[derive(Default)]
struct PipeState {
    inner: Mutex<PipeInner>,
    cv: Condvar,
}

#[derive(Default)]
struct PipeInner {
    /// Outstanding I/O jobs per flat task index (0 = not pending).
    remaining: Vec<usize>,
    /// Tasks whose last cold page landed, in completion order.
    ready: VecDeque<usize>,
}

/// Batch assembler: fans the per-(row, head) assemblies of one decode
/// step across the engine thread pool. Each flat task index owns a
/// dedicated [`TaskSlot`] (scratch + exec buffer + stats), so steady-
/// state decode touches no contended lock and performs no allocation:
/// the `RwLock` is only write-locked to grow the slot vector when the
/// batch widens, and each slot's `Mutex` is uncontended by construction
/// (one task per slot).
pub struct BatchAssembler {
    pool: Arc<ThreadPool>,
    parallel: bool,
    pipelined: bool,
    pipe: Arc<PipeState>,
    slots: RwLock<Vec<Mutex<TaskSlot>>>,
}

impl BatchAssembler {
    pub fn new(pool: Arc<ThreadPool>, parallel: bool) -> BatchAssembler {
        BatchAssembler {
            pool,
            parallel,
            pipelined: false,
            pipe: Arc::new(PipeState::default()),
            slots: RwLock::new(Vec::new()),
        }
    }

    pub fn parallel(&self) -> bool {
        self.parallel
    }

    pub fn set_parallel(&mut self, parallel: bool) {
        self.parallel = parallel;
    }

    /// Whether the stage-decoupled (select → async I/O → gather)
    /// executor is armed.
    pub fn pipelined(&self) -> bool {
        self.pipelined
    }

    /// Arm/disarm the pipelined executor. Works with or without
    /// `parallel`: in serial mode Phase A/B run as plain loops on the
    /// caller's thread (no scope boxing), which keeps the warm all-hot
    /// pipelined path allocation-free.
    pub fn set_pipelined(&mut self, pipelined: bool) {
        self.pipelined = pipelined;
    }

    /// Assemble every task's `(row, head)` slice of `wi`. `qg_all` is
    /// `[tasks, group, d]` flat. Returns the aggregate data-movement
    /// stats of the whole batch.
    pub fn assemble_into(
        &self,
        tasks: &[HeadTask<'_>],
        qg_all: &[f32],
        shape: AssembleShape,
        wi: &mut WaveInputs,
    ) -> AccessStats {
        let n = tasks.len();
        let gd = shape.group * shape.d;
        assert_eq!(qg_all.len(), n * gd, "qg_all shape mismatch");
        // Every field the raw-pointer slicing will carve must be large
        // enough — WaveInputs' fields are public, so an inconsistently
        // sized input must fail loudly here, not write out of bounds.
        let (ned, md) = (shape.ne * shape.d, shape.m_cap * shape.d);
        assert!(wi.kx.len() >= n * ned, "WaveInputs.kx too small for batch");
        assert!(wi.vx.len() >= n * ned, "WaveInputs.vx too small for batch");
        assert!(wi.kmask.len() >= n * shape.ne, "WaveInputs.kmask too small for batch");
        assert!(wi.cent.len() >= n * md, "WaveInputs.cent too small for batch");
        assert!(wi.vsum.len() >= n * md, "WaveInputs.vsum too small for batch");
        assert!(wi.csize.len() >= n * shape.m_cap, "WaveInputs.csize too small for batch");
        assert!(wi.emask.len() >= n * shape.m_cap, "WaveInputs.emask too small for batch");
        let ptrs = WavePtrs::of(wi);
        if self.slots.read().unwrap().len() < n {
            let mut slots = self.slots.write().unwrap();
            while slots.len() < n {
                slots.push(Mutex::new(TaskSlot::default()));
            }
        }
        let slots = self.slots.read().unwrap();
        if self.pipelined && self.pool.n_io_threads() > 0 {
            // ── Stage-decoupled pipeline ─────────────────────────────
            // Phase A (select): every task runs zone selection; the
            // moment a task's selection completes, its spilled pages
            // are issued as async reads on the pool's dedicated I/O
            // lane. Tasks with no cold pages gather inline — the warm
            // all-hot path submits nothing, queues nothing, and (after
            // warmup) allocates nothing. Phase B (gather): cold tasks
            // drain in I/O *completion* order, so whichever head's
            // pages land first gathers first while slower reads still
            // stream in. The merge order is fixed by the disjoint
            // WaveInputs slice layout, never by drain order — outputs
            // are bit-identical to the sequential path by construction.
            {
                let mut inner = self.pipe.inner.lock().unwrap();
                inner.remaining.clear();
                inner.remaining.resize(n, 0);
                inner.ready.clear();
            }
            let n_cold = AtomicUsize::new(0);
            let pipe = &self.pipe;
            let pool = &self.pool;
            let select_run = |t: usize| {
                // Uncontended by construction: flat task `t` is the
                // only user of slot `t` within this scope.
                let mut slot = slots[t].lock().unwrap();
                let slot = &mut *slot;
                if slot.eb.d() != shape.d {
                    slot.eb = ExecBuffer::new(shape.d);
                }
                slot.select_ns = select_head(
                    tasks[t],
                    &qg_all[t * gd..(t + 1) * gd],
                    shape,
                    &mut slot.scratch,
                );
                cold_blocks_of(tasks[t], &slot.scratch, &mut slot.cold);
                if slot.cold.is_empty() {
                    // SAFETY: task `t` is unique within this scope, and
                    // `wi` is mutably borrowed by `assemble_into` for
                    // the scope's whole lifetime — the slices are
                    // disjoint and live long enough.
                    let mut out = unsafe { ptrs.slices(t, shape) };
                    slot.stats =
                        gather_head(tasks[t], shape, &slot.scratch, &mut slot.eb, &mut out);
                    slot.stats.select_ns = slot.select_ns;
                } else {
                    n_cold.fetch_add(1, Ordering::Relaxed);
                    // Full count installed before any job can decrement
                    // it, so the countdown cannot hit zero early.
                    pipe.inner.lock().unwrap().remaining[t] = slot.cold.len();
                    let arena = tasks[t].index.arena();
                    for &bid in &slot.cold {
                        let arena = Arc::clone(arena);
                        let pipe = Arc::clone(pipe);
                        pool.submit_io(move || {
                            // Countdown in a drop guard: a panicking
                            // read still releases the task, so Phase B
                            // can never hang on a lost decrement.
                            struct Done {
                                pipe: Arc<PipeState>,
                                t: usize,
                            }
                            impl Drop for Done {
                                fn drop(&mut self) {
                                    let mut inner = self.pipe.inner.lock().unwrap();
                                    inner.remaining[self.t] -= 1;
                                    if inner.remaining[self.t] == 0 {
                                        inner.ready.push_back(self.t);
                                        self.pipe.cv.notify_one();
                                    }
                                }
                            }
                            let _done = Done { pipe, t };
                            arena.prefetch(bid);
                        });
                    }
                }
            };
            if self.parallel && n > 1 {
                self.pool.scope_for_each(n, &select_run);
            } else {
                for t in 0..n {
                    select_run(t);
                }
            }
            let nc = n_cold.load(Ordering::Relaxed);
            if nc > 0 {
                let drain = |_j: usize| {
                    let t = {
                        let mut inner = pipe.inner.lock().unwrap();
                        loop {
                            if let Some(t) = inner.ready.pop_front() {
                                break t;
                            }
                            inner = pipe.cv.wait(inner).unwrap();
                        }
                    };
                    let mut slot = slots[t].lock().unwrap();
                    let slot = &mut *slot;
                    // SAFETY: each ready task index is popped exactly
                    // once across the drain jobs, so `t` stays unique;
                    // `wi` outlives the scope as above.
                    let mut out = unsafe { ptrs.slices(t, shape) };
                    slot.stats =
                        gather_head(tasks[t], shape, &slot.scratch, &mut slot.eb, &mut out);
                    slot.stats.select_ns = slot.select_ns;
                };
                if self.parallel && nc > 1 {
                    self.pool.scope_for_each(nc, &drain);
                } else {
                    for j in 0..nc {
                        drain(j);
                    }
                }
            }
        } else {
            let run = |t: usize| {
                // Uncontended by construction: flat task `t` is the only
                // user of slot `t` within this scope.
                let mut slot = slots[t].lock().unwrap();
                let slot = &mut *slot;
                if slot.eb.d() != shape.d {
                    slot.eb = ExecBuffer::new(shape.d);
                }
                // SAFETY: task `t` is unique within this scope, and `wi`
                // is mutably borrowed by `assemble_into` for the whole
                // scope lifetime — the slices are disjoint and live long
                // enough.
                let mut out = unsafe { ptrs.slices(t, shape) };
                slot.stats = assemble_head(
                    tasks[t],
                    &qg_all[t * gd..(t + 1) * gd],
                    shape,
                    &mut slot.scratch,
                    &mut slot.eb,
                    &mut out,
                );
            };
            if self.parallel && n > 1 {
                self.pool.scope_for_each(n, &run);
            } else {
                for t in 0..n {
                    run(t);
                }
            }
        }
        let mut agg = AccessStats::default();
        for slot in slots.iter().take(n) {
            agg.add(&slot.lock().unwrap().stats);
        }
        agg
    }
}
