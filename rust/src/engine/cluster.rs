//! Cluster serving: N real [`LiveEngine`] replicas behind the
//! [`Router`]/[`Scheduler`] coordinator (DESIGN.md §2 "Cluster serving &
//! migration"). Each replica owns its engine, KV arena and scheduler;
//! the coordinator owns only routing state — the paper's §4.5 modularity
//! argument made concrete: no KV ever needs to be consistent across
//! replicas, so the cross-replica protocol reduces to three verbs:
//!
//! * **steal** — a replica whose admission gate defers its head-of-queue
//!   offers the request (still `Queued`, so no KV has materialized) to
//!   the least-loaded live peer instead of spinning on `Action::Defer`.
//! * **migrate** — a mid-decode session serializes through
//!   [`LiveEngine::export_session`] (spill-page block format + wave-index
//!   metadata) and resumes bit-identically on the target replica.
//! * **recover** — a killed replica loses its engine (all KV state); the
//!   coordinator still owns its scheduler, so the lost sessions re-prefill
//!   idempotently on survivors and teacher-force replay their
//!   already-generated tokens (decode is deterministic, so the replay
//!   reconstructs the exact KV the dead replica held).

use super::live::LiveEngine;
use crate::config::CapacityConfig;
use crate::coordinator::{Action, Batcher, Phase, Request, Router, Scheduler};
use crate::kvcache::DEFAULT_TENANT;
use crate::util::stats::{LogHistogram, Sample};
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Geometry and policy of a replica cluster.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub replicas: usize,
    /// Decode batch buckets per replica.
    pub buckets: Vec<usize>,
    /// Decode-pool admission cap per replica.
    pub max_batch: usize,
    /// Virtual seconds one coordinator round advances (latency
    /// accounting only — real compute time is whatever PJRT takes).
    pub dt_s: f64,
    /// Offer gate-deferred requests to the least-loaded live peer.
    pub steal: bool,
    /// Per-replica arena budget; arms the single-tier admission gate
    /// (stealing needs a gate that can defer). `None` = unbounded,
    /// admit-everything replicas.
    pub capacity: Option<CapacityConfig>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 2,
            buckets: vec![1, 2, 4, 8],
            max_batch: 8,
            dt_s: 0.05,
            steal: true,
            capacity: None,
        }
    }
}

/// One replica: a live engine plus the scheduler that owns its sessions.
struct Replica {
    engine: LiveEngine,
    sched: Scheduler,
}

/// Terminal record of a request (kept by the coordinator so a replica's
/// death cannot lose completed work).
#[derive(Clone, Debug)]
struct DoneRec {
    tokens: Vec<i32>,
    arrive_s: f64,
    first_token_s: f64,
    done_s: f64,
    rejected: bool,
}

#[derive(Clone, Debug, Default)]
struct ClusterStats {
    steals: u64,
    migrations: u64,
    migrated_bytes: u64,
    failures: u64,
    recovered_sessions: u64,
    replayed_tokens: u64,
    replay_divergence: u64,
    prefill_failures: u64,
}

/// What a measured cluster run observed — the shape of
/// [`super::sim::LoadReport`], so modelled and measured cluster behaviour
/// compare field-for-field (EXPERIMENTS.md "Cluster serving").
#[derive(Clone, Debug)]
pub struct ClusterRunReport {
    pub replicas: usize,
    pub n_requests: usize,
    pub completed: usize,
    pub rejected: usize,
    /// Virtual makespan (rounds × `dt_s`).
    pub makespan_s: f64,
    pub req_per_s: f64,
    pub mean_latency_s: f64,
    pub p99_latency_s: f64,
    /// Mean time-to-first-token over completed requests (infinite when
    /// nothing completed — never NaN).
    pub mean_ttft_s: f64,
    /// TTFT tail percentiles from a streaming [`LogHistogram`] (fixed
    /// memory regardless of run length; infinite when empty, never NaN).
    pub ttft_p50_s: f64,
    pub ttft_p95_s: f64,
    pub ttft_p99_s: f64,
    /// Per-request mean TPOT percentiles — `(done_s - first_token_s) /
    /// (tokens - 1)` per completed multi-token request, observed into a
    /// streaming histogram. Same empty convention as TTFT.
    pub tpot_p50_s: f64,
    pub tpot_p95_s: f64,
    pub tpot_p99_s: f64,
    pub steals: u64,
    pub migrations: u64,
    pub migrated_bytes: u64,
    pub failures: u64,
    pub recovered_sessions: u64,
    pub replayed_tokens: u64,
    /// Replayed tokens that disagreed with the dead replica's record
    /// (must be 0: decode is deterministic).
    pub replay_divergence: u64,
    pub prefill_failures: u64,
}

/// A sharded serving cluster over real engines.
pub struct ClusterEngine {
    replicas: Vec<Option<Replica>>,
    router: Router,
    /// session id → replica currently serving it.
    home: HashMap<u64, usize>,
    done: HashMap<u64, DoneRec>,
    now_s: f64,
    dt_s: f64,
    steal: bool,
    n_requests: usize,
    stats: ClusterStats,
}

impl ClusterEngine {
    /// Build `cfg.replicas` live engines from `artifacts_dir` (Wave
    /// mode), each with its own arena, scheduler and — when
    /// `cfg.capacity` is set — admission gate.
    pub fn new(artifacts_dir: &str, cfg: &ClusterConfig) -> Result<ClusterEngine> {
        assert!(cfg.replicas > 0, "a cluster needs at least one replica");
        let mut replicas = Vec::with_capacity(cfg.replicas);
        for _ in 0..cfg.replicas {
            let engine = LiveEngine::new(artifacts_dir, super::live::AttnMode::Wave)?;
            let batcher = Batcher::new(&cfg.buckets, cfg.max_batch);
            let sched = match &cfg.capacity {
                Some(cap) => {
                    engine.apply_capacity(cap, &[DEFAULT_TENANT]);
                    Scheduler::with_admission(
                        batcher,
                        std::sync::Arc::clone(engine.arena()),
                        engine.admission_config(cap),
                    )
                }
                None => Scheduler::new(batcher),
            };
            replicas.push(Some(Replica { engine, sched }));
        }
        Ok(ClusterEngine {
            router: Router::new(cfg.replicas),
            replicas,
            home: HashMap::new(),
            done: HashMap::new(),
            now_s: 0.0,
            dt_s: cfg.dt_s,
            steal: cfg.steal,
            n_requests: 0,
            stats: ClusterStats::default(),
        })
    }

    /// Route one request to a replica (least-loaded live). Returns the
    /// replica index it homed on.
    pub fn submit(&mut self, req: Request) -> usize {
        let w = self.router.route_with_prefix(None);
        let id = req.id;
        self.replicas[w]
            .as_mut()
            .expect("router never routes to a downed replica")
            .sched
            .submit(req, self.now_s);
        self.home.insert(id, w);
        self.n_requests += 1;
        w
    }

    /// The replica currently serving `id` (none once finished or lost).
    pub fn home_of(&self, id: u64) -> Option<usize> {
        self.home.get(&id).copied()
    }

    /// Live (not-killed) replicas.
    pub fn n_live(&self) -> usize {
        self.router.live_workers()
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// A completed session's generated tokens.
    pub fn output(&self, id: u64) -> Option<&[i32]> {
        self.done.get(&id).map(|r| r.tokens.as_slice())
    }

    /// Every live replica's scheduler has drained.
    pub fn is_done(&self) -> bool {
        self.replicas.iter().flatten().all(|rep| rep.sched.all_done())
    }

    fn record_done(done: &mut HashMap<u64, DoneRec>, sched: &Scheduler, id: u64) {
        if let Some(s) = sched.session(id) {
            done.insert(
                id,
                DoneRec {
                    tokens: s.generated.clone(),
                    arrive_s: s.req.arrive_s,
                    first_token_s: s.first_token_s,
                    done_s: s.done_s,
                    rejected: s.rejected,
                },
            );
        }
    }

    /// One coordinator round: every live replica takes its next
    /// scheduler action (one prefill or one decode batch), gate-deferred
    /// heads are offered to peers, and finished sessions reclaim their
    /// KV. Returns whether any replica did work.
    pub fn step(&mut self) -> Result<bool> {
        self.now_s += self.dt_s;
        let n = self.replicas.len();
        let mut worked = false;
        for r in 0..n {
            if self.replicas[r].is_none() {
                continue;
            }
            let action = self.replicas[r].as_mut().unwrap().sched.next_action();
            match action {
                Action::Prefill(id) => {
                    worked = true;
                    let (tenant, prompt) = {
                        let s = self.replicas[r].as_ref().unwrap().sched.session(id).unwrap();
                        (s.req.tenant, s.req.prompt.clone())
                    };
                    let res = self.replicas[r]
                        .as_mut()
                        .unwrap()
                        .engine
                        .prefill_for(id, tenant, &prompt);
                    match res {
                        Ok(first) => self.replicas[r]
                            .as_mut()
                            .unwrap()
                            .sched
                            .prefill_done(id, first, self.now_s),
                        Err(_) => {
                            // the gate admitted what the engine refused
                            // (estimate too tight): fail the request
                            // instead of deadlocking the queue
                            self.stats.prefill_failures += 1;
                            if let Some(s) =
                                self.replicas[r].as_mut().unwrap().sched.take_session(id)
                            {
                                self.done.insert(
                                    id,
                                    DoneRec {
                                        tokens: s.generated.clone(),
                                        arrive_s: s.req.arrive_s,
                                        first_token_s: f64::NAN,
                                        done_s: self.now_s,
                                        rejected: true,
                                    },
                                );
                            }
                            self.router.complete(r);
                            self.home.remove(&id);
                        }
                    }
                }
                Action::DecodeBatch(ids, bucket) => {
                    worked = true;
                    let out = self.replicas[r]
                        .as_mut()
                        .unwrap()
                        .engine
                        .decode_step(&ids, bucket)?;
                    let rep = self.replicas[r].as_mut().unwrap();
                    for (i, id) in ids.iter().enumerate() {
                        rep.sched.token_decoded(*id, out[i], self.now_s);
                    }
                }
                Action::Defer | Action::Idle => {}
            }
            // donor side of work stealing, checked every round: a busy
            // replica decodes instead of returning `Defer`, so the
            // gate-blocked head is probed directly (`steal_deferred`
            // pops it only if the gate defers it right now — it has no
            // KV yet, so moving it is a bookkeeping edit). Load-gated so
            // a request only moves where it reduces imbalance, which
            // also stops steal ping-pong between two full replicas.
            if self.steal {
                if let Some(t) = self.router.steal_target(r) {
                    if self.router.load(t) + 1 < self.router.load(r) {
                        if let Some(req) =
                            self.replicas[r].as_mut().unwrap().sched.steal_deferred()
                        {
                            let id = req.id;
                            self.replicas[t].as_mut().unwrap().sched.submit(req, self.now_s);
                            self.router.note_stolen(r, t);
                            self.home.insert(id, t);
                            self.stats.steals += 1;
                        }
                    }
                }
            }
            // reclamation: finished sessions return their KV blocks and
            // free a router slot (this is what re-admits deferred work)
            let fin = self.replicas[r].as_mut().unwrap().sched.take_finished();
            for id in fin {
                Self::record_done(
                    &mut self.done,
                    &self.replicas[r].as_ref().unwrap().sched,
                    id,
                );
                self.replicas[r].as_mut().unwrap().engine.finish_session(id);
                self.router.complete(r);
                self.home.remove(&id);
            }
        }
        Ok(worked)
    }

    /// Drive rounds until every live scheduler drains (or `max_rounds`).
    pub fn run_until_done(&mut self, max_rounds: usize) -> Result<ClusterRunReport> {
        for _ in 0..max_rounds {
            if self.is_done() {
                return Ok(self.report());
            }
            self.step()?;
        }
        if self.is_done() {
            Ok(self.report())
        } else {
            Err(anyhow!("cluster did not quiesce in {max_rounds} rounds"))
        }
    }

    /// Live-migrate session `id` to replica `to`: bookkeeping moves
    /// through `Scheduler::take_session`/`adopt_session`, KV moves
    /// through the serialized snapshot (a `Queued` session has no KV and
    /// moves for free). Returns the snapshot bytes that crossed the
    /// migration channel. The import lands before the source releases
    /// anything, so a failed migration leaves the session serving where
    /// it was.
    pub fn migrate_session(&mut self, id: u64, to: usize) -> Result<usize> {
        let from = self
            .home
            .get(&id)
            .copied()
            .ok_or_else(|| anyhow!("session {id} is not live on any replica"))?;
        if from == to {
            return Ok(0);
        }
        if to >= self.replicas.len() || self.replicas[to].is_none() {
            return Err(anyhow!("target replica {to} is not live"));
        }
        let phase = self.replicas[from]
            .as_ref()
            .unwrap()
            .sched
            .session(id)
            .map(|s| s.phase)
            .ok_or_else(|| anyhow!("session {id} missing from its home scheduler"))?;
        let moved = match phase {
            Phase::Queued => 0,
            Phase::Decode => {
                let (snap, tenant) = {
                    let rep = self.replicas[from].as_ref().unwrap();
                    let snap = rep
                        .engine
                        .export_session(id)
                        .ok_or_else(|| anyhow!("session {id} has no engine state"))?;
                    (snap, rep.sched.session(id).unwrap().req.tenant)
                };
                let bytes = snap.payload_bytes();
                self.replicas[to]
                    .as_mut()
                    .unwrap()
                    .engine
                    .import_session(id, tenant, &snap)?;
                self.replicas[from].as_mut().unwrap().engine.finish_session(id);
                bytes
            }
            Phase::Prefill | Phase::Preempted | Phase::Done => {
                return Err(anyhow!("session {id} cannot migrate in phase {phase:?}"))
            }
        };
        let s = self.replicas[from]
            .as_mut()
            .unwrap()
            .sched
            .take_session(id)
            .expect("session present");
        self.replicas[to].as_mut().unwrap().sched.adopt_session(s, self.now_s);
        self.router.note_stolen(from, to);
        self.home.insert(id, to);
        self.stats.migrations += 1;
        self.stats.migrated_bytes += moved as u64;
        Ok(moved)
    }

    /// Kill replica `victim` mid-service: its engine (all KV state)
    /// drops on the floor, and every unfinished session re-homes to a
    /// survivor — `Queued` sessions simply requeue; mid-decode sessions
    /// re-prefill from their prompt and teacher-force replay their
    /// already-generated tokens, reconstructing the lost KV exactly
    /// (decode is deterministic). Idempotent per session: a survivor
    /// that cannot hold the re-prefill right now restarts the session
    /// from its queue instead, and the regenerated tokens are identical.
    /// Returns how many sessions were recovered.
    pub fn kill_replica(&mut self, victim: usize) -> Result<usize> {
        if victim >= self.replicas.len() || self.replicas[victim].is_none() {
            return Err(anyhow!("replica {victim} is not live"));
        }
        if self.router.live_workers() <= 1 {
            return Err(anyhow!("cannot kill the last live replica"));
        }
        let mut dead = self.replicas[victim].take().unwrap();
        // finished-but-undrained events survive the failure: the
        // coordinator records them before the scheduler drops
        for id in dead.sched.take_finished() {
            Self::record_done(&mut self.done, &dead.sched, id);
            self.home.remove(&id);
        }
        self.router.mark_down(victim);
        self.stats.failures += 1;
        let lost = dead.sched.drain_unfinished();
        drop(dead); // the engine — and every KV block it held — dies here
        let mut recovered = 0usize;
        for mut s in lost {
            let id = s.req.id;
            let target = self
                .router
                .steal_target(victim)
                .expect("a live replica exists (checked above)");
            match s.phase {
                Phase::Decode => {
                    let tr = self.replicas[target].as_mut().unwrap();
                    match tr.engine.prefill_for(id, s.req.tenant, &s.req.prompt) {
                        Ok(first) => {
                            if first != s.generated[0] {
                                self.stats.replay_divergence += 1;
                            }
                            for w in s.generated.windows(2) {
                                tr.engine.force_token(id, w[0]);
                                let t = tr.engine.decode_step(&[id], 1)?[0];
                                if t != w[1] {
                                    self.stats.replay_divergence += 1;
                                }
                                self.stats.replayed_tokens += 1;
                            }
                            // the next scheduled decode consumes exactly
                            // the token the dead replica was about to
                            tr.engine.force_token(id, *s.generated.last().unwrap());
                            tr.sched.adopt_session(s, self.now_s);
                        }
                        Err(_) => {
                            // survivor is full right now: restart from
                            // the queue — deterministic decode makes the
                            // regenerated tokens identical
                            s.generated.clear();
                            s.phase = Phase::Queued;
                            s.first_token_s = f64::NAN;
                            tr.sched.adopt_session(s, self.now_s);
                        }
                    }
                }
                _ => {
                    // Queued (or in-flight Prefill, which adopt requeues):
                    // no KV existed, nothing to reconstruct
                    self.replicas[target]
                        .as_mut()
                        .unwrap()
                        .sched
                        .adopt_session(s, self.now_s);
                }
            }
            self.router.note_stolen(victim, target);
            self.home.insert(id, target);
            self.stats.recovered_sessions += 1;
            recovered += 1;
        }
        Ok(recovered)
    }

    /// The measured report (callable mid-run; makespan is rounds so far).
    pub fn report(&self) -> ClusterRunReport {
        let mut lat = Sample::new();
        let mut ttft = Sample::new();
        let mut ttft_hist = LogHistogram::latency_s();
        let mut tpot_hist = LogHistogram::latency_s();
        let mut completed = 0usize;
        let mut rejected = 0usize;
        for rec in self.done.values() {
            if rec.rejected {
                rejected += 1;
                continue;
            }
            completed += 1;
            lat.add(rec.done_s - rec.arrive_s);
            if rec.first_token_s.is_finite() {
                ttft.add(rec.first_token_s - rec.arrive_s);
                ttft_hist.observe(rec.first_token_s - rec.arrive_s);
                if rec.tokens.len() > 1 {
                    tpot_hist
                        .observe((rec.done_s - rec.first_token_s) / (rec.tokens.len() - 1) as f64);
                }
            }
        }
        // histogram percentile, with the same empty convention as
        // `mean_ttft_s`: no observations → infinite, never NaN
        let pct = |h: &LogHistogram, p: f64| {
            if h.is_empty() {
                f64::INFINITY
            } else {
                h.percentile(p)
            }
        };
        // the simulate_cluster convention (and its NaN regression): no
        // completions → infinite latencies, never `inf × 0`
        let (mean, p99) = if completed > 0 {
            (lat.mean(), lat.percentile(99.0))
        } else {
            (f64::INFINITY, f64::INFINITY)
        };
        let mean_ttft = if ttft.is_empty() { f64::INFINITY } else { ttft.mean() };
        ClusterRunReport {
            replicas: self.replicas.len(),
            n_requests: self.n_requests,
            completed,
            rejected,
            makespan_s: self.now_s,
            req_per_s: completed as f64 / self.now_s.max(1e-9),
            mean_latency_s: mean,
            p99_latency_s: p99,
            mean_ttft_s: mean_ttft,
            ttft_p50_s: pct(&ttft_hist, 50.0),
            ttft_p95_s: pct(&ttft_hist, 95.0),
            ttft_p99_s: pct(&ttft_hist, 99.0),
            tpot_p50_s: pct(&tpot_hist, 50.0),
            tpot_p95_s: pct(&tpot_hist, 95.0),
            tpot_p99_s: pct(&tpot_hist, 99.0),
            steals: self.stats.steals,
            migrations: self.stats.migrations,
            migrated_bytes: self.stats.migrated_bytes,
            failures: self.stats.failures,
            recovered_sessions: self.stats.recovered_sessions,
            replayed_tokens: self.stats.replayed_tokens,
            replay_divergence: self.stats.replay_divergence,
            prefill_failures: self.stats.prefill_failures,
        }
    }
}

impl ClusterRunReport {
    /// Sanity predicate the failure-injection tests assert: every
    /// latency/throughput field is a number (the cluster-sim NaN bugs
    /// this PR fixed must not reappear in the measured path).
    pub fn finite_or_empty(&self) -> bool {
        let lat_ok = if self.completed > 0 {
            self.mean_latency_s.is_finite() && self.p99_latency_s.is_finite()
        } else {
            self.mean_latency_s.is_infinite() && self.p99_latency_s.is_infinite()
        };
        lat_ok
            && !self.mean_ttft_s.is_nan()
            && !self.ttft_p50_s.is_nan()
            && !self.ttft_p95_s.is_nan()
            && !self.ttft_p99_s.is_nan()
            && !self.tpot_p50_s.is_nan()
            && !self.tpot_p95_s.is_nan()
            && !self.tpot_p99_s.is_nan()
            && !self.req_per_s.is_nan()
            && !self.makespan_s.is_nan()
    }
}
