//! Minimal dense f32 tensor used across the engine (host-side staging for
//! PJRT literals, pure-Rust attention, index math). Row-major.

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn filled(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < dim, "index {x} out of bound {dim} at axis {i}");
            off = off * dim + x;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    /// Contiguous row slice: all trailing-axis elements at a leading index.
    /// e.g. for shape [A, B, D], `row(&[a, b])` is the D-vector at (a, b).
    pub fn row(&self, lead: &[usize]) -> &[f32] {
        let trailing: usize = self.shape[lead.len()..].iter().product();
        let mut off = 0;
        for (&x, &dim) in lead.iter().zip(&self.shape) {
            off = off * dim + x;
        }
        &self.data[off * trailing..(off + 1) * trailing]
    }

    pub fn row_mut(&mut self, lead: &[usize]) -> &mut [f32] {
        let trailing: usize = self.shape[lead.len()..].iter().product();
        let mut off = 0;
        for (&x, &dim) in lead.iter().zip(&self.shape) {
            off = off * dim + x;
        }
        &mut self.data[off * trailing..(off + 1) * trailing]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }
}

/// Dot product via the process-pinned kernel backend (`kernels::active`).
/// The scalar backend preserves the historical 4-accumulator order; pin
/// `RETRO_KERNELS=scalar` for bit-exact reproduction of old outputs.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    crate::kernels::dot(a, b)
}

/// y += alpha * x via the process-pinned kernel backend.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    crate::kernels::axpy(alpha, x, y)
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Scale in place.
#[inline]
pub fn scale(a: &mut [f32], s: f32) {
    for v in a {
        *v *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        t.set(&[1, 2, 3], 7.0);
        assert_eq!(t.at(&[1, 2, 3]), 7.0);
        assert_eq!(t.offset(&[1, 2, 3]), 1 * 12 + 2 * 4 + 3);
    }

    #[test]
    fn row_slice() {
        let data: Vec<f32> = (0..24).map(|x| x as f32).collect();
        let t = Tensor::from_vec(&[2, 3, 4], data);
        assert_eq!(t.row(&[1, 2]), &[20.0, 21.0, 22.0, 23.0]);
        assert_eq!(t.row(&[0]), (0..12).map(|x| x as f32).collect::<Vec<_>>().as_slice());
    }

    #[test]
    fn row_mut_writes() {
        let mut t = Tensor::zeros(&[2, 2]);
        t.row_mut(&[1]).copy_from_slice(&[5.0, 6.0]);
        assert_eq!(t.at(&[1, 0]), 5.0);
        assert_eq!(t.at(&[1, 1]), 6.0);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let a: Vec<f32> = (0..13).map(|x| x as f32 * 0.5).collect();
        let b: Vec<f32> = (0..13).map(|x| (13 - x) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn axpy_accumulates() {
        let x = vec![1.0, 2.0];
        let mut y = vec![10.0, 20.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, vec![10.5, 21.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch() {
        Tensor::from_vec(&[2, 2], vec![0.0; 3]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.at(&[2, 1]), 5.0);
    }
}
