//! Attention-sparsity analysis utilities (paper Figures 3, 4 and 8):
//! top-k mass, heavy-hitter sets, step-to-step overlap.

use super::attention_weights;

/// Indices of the `k` largest attention weights.
pub fn top_k_indices(weights: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..weights.len()).collect();
    let k = k.min(weights.len());
    idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
        weights[b].partial_cmp(&weights[a]).unwrap()
    });
    idx.truncate(k);
    idx.sort_by(|&a, &b| weights[b].partial_cmp(&weights[a]).unwrap());
    idx
}

/// Total attention mass captured by the top-k weights.
pub fn top_k_mass(weights: &[f32], k: usize) -> f64 {
    top_k_indices(weights, k).iter().map(|&i| weights[i] as f64).sum()
}

/// Smallest number of tokens covering `mass` of the attention
/// distribution — the per-query sparsity ratio measure of Figure 4(b).
pub fn tokens_for_mass(weights: &[f32], mass: f64) -> usize {
    let mut sorted: Vec<f32> = weights.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut acc = 0.0f64;
    for (i, w) in sorted.iter().enumerate() {
        acc += *w as f64;
        if acc >= mass {
            return i + 1;
        }
    }
    weights.len()
}

/// Jaccard-style overlap |A ∩ B| / k of two top-k sets (Figure 3's
/// "31% overlap across decoding steps" measurement).
pub fn top_k_overlap(a: &[usize], b: &[usize]) -> f64 {
    if a.is_empty() {
        return 0.0;
    }
    let set: std::collections::HashSet<usize> = a.iter().copied().collect();
    let inter = b.iter().filter(|x| set.contains(x)).count();
    inter as f64 / a.len() as f64
}

/// Recall of ground-truth heavy hitters within a selected token set.
pub fn recall(truth: &[usize], selected: &[usize]) -> f64 {
    if truth.is_empty() {
        return 1.0;
    }
    let set: std::collections::HashSet<usize> = selected.iter().copied().collect();
    truth.iter().filter(|t| set.contains(t)).count() as f64 / truth.len() as f64
}

/// Per-query sparsity summary for one head.
pub struct SparsityProfile {
    pub top100_mass: f64,
    pub tokens_for_90: usize,
    pub tokens_for_99: usize,
}

pub fn profile(q: &[f32], keys: &[f32], d: usize) -> SparsityProfile {
    let w = attention_weights(q, keys, d);
    SparsityProfile {
        top100_mass: top_k_mass(&w, 100),
        tokens_for_90: tokens_for_mass(&w, 0.90),
        tokens_for_99: tokens_for_mass(&w, 0.99),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_orders_by_weight() {
        let w = vec![0.1, 0.5, 0.05, 0.3, 0.05];
        assert_eq!(top_k_indices(&w, 3), vec![1, 3, 0]);
        assert!((top_k_mass(&w, 2) - 0.8).abs() < 1e-6);
    }

    #[test]
    fn top_k_handles_k_larger_than_len() {
        let w = vec![0.6, 0.4];
        assert_eq!(top_k_indices(&w, 10).len(), 2);
    }

    #[test]
    fn tokens_for_mass_concentrated() {
        let w = vec![0.9, 0.05, 0.03, 0.02];
        assert_eq!(tokens_for_mass(&w, 0.5), 1);
        assert_eq!(tokens_for_mass(&w, 0.949), 2);
        assert_eq!(tokens_for_mass(&w, 1.0), 4);
    }

    #[test]
    fn overlap_and_recall() {
        let a = vec![1, 2, 3, 4];
        let b = vec![3, 4, 5, 6];
        assert!((top_k_overlap(&a, &b) - 0.5).abs() < 1e-12);
        assert!((recall(&a, &b) - 0.5).abs() < 1e-12);
        assert_eq!(recall(&[], &b), 1.0);
    }
}
