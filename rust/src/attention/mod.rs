//! Pure-Rust attention: the oracle for the simulation path and tests.
//!
//! The PJRT-executed L1 kernel computes the same tripartite merge on the
//! live path; this module is its host-side twin used by (a) the hardware
//! simulator (which needs outputs, not timing, from real math), (b) the
//! baselines, and (c) accuracy experiments at contexts too long for live
//! execution on one CPU core.

pub mod sparsity;

use crate::kernels::{self, Backend, ExpAxpy};
use crate::tensor::{axpy, dot};

/// Reusable score buffers for the two-pass merge. One per decode task;
/// steady-state reuse keeps the hot path allocation-free.
#[derive(Default)]
pub struct MergeScratch {
    ex: Vec<f32>,
    est: Vec<f32>,
}

/// Numerically-stable softmax over `scores` in place; returns the
/// normalizing denominator in max-shifted space.
pub fn softmax_inplace(scores: &mut [f32]) -> f32 {
    let m = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut denom = 0.0;
    for s in scores.iter_mut() {
        *s = (*s - m).exp();
        denom += *s;
    }
    let inv = 1.0 / denom.max(1e-30);
    for s in scores.iter_mut() {
        *s *= inv;
    }
    denom
}

/// Full attention for one query against a [T, d] key/value set.
/// `q` is unscaled (scaling by 1/sqrt(d) applied here).
pub fn full_attention(q: &[f32], keys: &[f32], vals: &[f32], d: usize, out: &mut [f32]) {
    let mut scratch = MergeScratch::default();
    full_attention_with(q, keys, vals, d, &mut scratch, out)
}

/// `full_attention` reusing caller scratch (alloc-free after warmup).
pub fn full_attention_with(
    q: &[f32],
    keys: &[f32],
    vals: &[f32],
    d: usize,
    scratch: &mut MergeScratch,
    out: &mut [f32],
) {
    full_attention_in(kernels::active(), q, keys, vals, d, scratch, out)
}

/// `full_attention` on an explicit backend (benches compare scalar vs
/// SIMD in one process; everything else goes through the pinned
/// `kernels::active()` via [`full_attention_with`]).
pub fn full_attention_in(
    bk: Backend,
    q: &[f32],
    keys: &[f32],
    vals: &[f32],
    d: usize,
    scratch: &mut MergeScratch,
    out: &mut [f32],
) {
    debug_assert_eq!(keys.len(), vals.len());
    debug_assert_eq!(out.len(), d);
    let scale = 1.0 / (d as f32).sqrt();
    let m = bk.score_rows(q, keys, d, scale, &mut scratch.ex);
    out.iter_mut().for_each(|o| *o = 0.0);
    if !m.is_finite() {
        return; // no tokens, or scores overflowed: emit zeros like the merge
    }
    let denom =
        bk.exp_axpy_rows(&ExpAxpy { scores: &scratch.ex, max: m, rows: vals, d }, out);
    let inv = (1.0 / denom.max(1e-30)) as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// Full attention weights (softmax over q·K/sqrt(d)) for analysis.
pub fn attention_weights(q: &[f32], keys: &[f32], d: usize) -> Vec<f32> {
    let t = keys.len() / d;
    let scale = 1.0 / (d as f32).sqrt();
    let mut scores: Vec<f32> = (0..t)
        .map(|i| dot(q, &keys[i * d..(i + 1) * d]) * scale)
        .collect();
    softmax_inplace(&mut scores);
    scores
}

/// Sparse attention over an explicit token subset (baselines): softmax is
/// computed over the selected tokens ONLY (no estimation), as in
/// Quest/InfiniGen/PQCache.
pub fn subset_attention(
    q: &[f32],
    keys: &[f32],
    vals: &[f32],
    d: usize,
    selected: &[usize],
    out: &mut [f32],
) {
    let scale = 1.0 / (d as f32).sqrt();
    let mut scores: Vec<f32> = selected
        .iter()
        .map(|&i| dot(q, &keys[i * d..(i + 1) * d]) * scale)
        .collect();
    softmax_inplace(&mut scores);
    out.iter_mut().for_each(|o| *o = 0.0);
    for (w, &i) in scores.iter().zip(selected) {
        axpy(*w, &vals[i * d..(i + 1) * d], out);
    }
}

/// Inputs to the tripartite merge for one (query, head) pair.
/// Exact tokens are referenced by index into `keys`/`vals`; estimated
/// clusters by index into the meta arrays.
pub struct TripartiteInputs<'a> {
    pub d: usize,
    /// [T, d] flat key/value storage
    pub keys: &'a [f32],
    pub vals: &'a [f32],
    /// exact-zone token indices (steady + retrieval zones)
    pub exact: &'a [usize],
    /// meta index: [M, d] centroids, [M, d] value sums, [M] sizes
    pub centroids: &'a [f32],
    pub vsum: &'a [f32],
    pub sizes: &'a [f32],
    /// cluster ids participating in the estimation zone
    pub estimated: &'a [usize],
}

/// Tripartite attention (paper Eq. 2-4): one softmax over
///   exact tokens:      exp(q.k)                -> value v
///   estimated cluster: s_j * exp(q.C_j) (denom), exp(q.C_j) * VS_j (num)
pub fn tripartite_attention(q: &[f32], inp: &TripartiteInputs, out: &mut [f32]) {
    let mut scratch = MergeScratch::default();
    tripartite_attention_with(q, inp, &mut scratch, out)
}

/// `tripartite_attention` reusing caller scratch (the decode hot path:
/// alloc-free after warmup).
pub fn tripartite_attention_with(
    q: &[f32],
    inp: &TripartiteInputs,
    scratch: &mut MergeScratch,
    out: &mut [f32],
) {
    tripartite_attention_in(kernels::active(), q, inp, scratch, out)
}

/// `tripartite_attention` on an explicit backend.
///
/// Fused two-pass merge: pass 1 scores both zones and tracks the shared
/// max; pass 2 does the exp + weighted-axpy accumulate with an f64
/// denominator, exact zone first then estimation zone, in index order —
/// the fixed reduction order both backends commit to.
pub fn tripartite_attention_in(
    bk: Backend,
    q: &[f32],
    inp: &TripartiteInputs,
    scratch: &mut MergeScratch,
    out: &mut [f32],
) {
    let d = inp.d;
    debug_assert_eq!(out.len(), d);
    let scale = 1.0 / (d as f32).sqrt();

    // pass 1: max for stability across both parts
    let m_ex = bk.score_indexed(q, inp.keys, d, scale, inp.exact, &mut scratch.ex);
    let m_est = bk.score_indexed(q, inp.centroids, d, scale, inp.estimated, &mut scratch.est);
    let m = m_ex.max(m_est);
    out.iter_mut().for_each(|o| *o = 0.0);
    if !m.is_finite() {
        return;
    }

    // pass 2: accumulate
    let ex = ExpAxpy { scores: &scratch.ex, max: m, rows: inp.vals, d };
    let mut denom = bk.exp_axpy(&ex, inp.exact, None, out);
    let est = ExpAxpy { scores: &scratch.est, max: m, rows: inp.vsum, d };
    denom += bk.exp_axpy(&est, inp.estimated, Some(inp.sizes), out);
    let inv = (1.0 / denom.max(1e-30)) as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::{cosine, rel_err};

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        rng.normal_vec(n)
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut s = vec![1.0, 2.0, 3.0, -100.0];
        softmax_inplace(&mut s);
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(s[2] > s[1] && s[1] > s[0]);
    }

    #[test]
    fn softmax_large_values_stable() {
        let mut s = vec![1e4, 1e4 + 1.0];
        softmax_inplace(&mut s);
        assert!(s.iter().all(|x| x.is_finite()));
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn full_attention_uniform_keys_averages_values() {
        let d = 4;
        let t = 8;
        let keys = vec![0.0; t * d]; // all scores equal -> uniform weights
        let mut vals = vec![0.0; t * d];
        for i in 0..t {
            vals[i * d] = i as f32;
        }
        let q = vec![1.0; d];
        let mut out = vec![0.0; d];
        full_attention(&q, &keys, &vals, d, &mut out);
        assert!((out[0] - 3.5).abs() < 1e-5);
    }

    #[test]
    fn subset_attention_full_subset_matches_full() {
        let mut rng = Rng::new(3);
        let (d, t) = (16, 50);
        let keys = randvec(&mut rng, t * d);
        let vals = randvec(&mut rng, t * d);
        let q = randvec(&mut rng, d);
        let mut a = vec![0.0; d];
        let mut b = vec![0.0; d];
        full_attention(&q, &keys, &vals, d, &mut a);
        let all: Vec<usize> = (0..t).collect();
        subset_attention(&q, &keys, &vals, d, &all, &mut b);
        assert!(rel_err(&b, &a) < 1e-5);
    }

    #[test]
    fn tripartite_all_exact_matches_full() {
        let mut rng = Rng::new(5);
        let (d, t) = (16, 64);
        let keys = randvec(&mut rng, t * d);
        let vals = randvec(&mut rng, t * d);
        let q = randvec(&mut rng, d);
        let mut full = vec![0.0; d];
        full_attention(&q, &keys, &vals, d, &mut full);
        let exact: Vec<usize> = (0..t).collect();
        let inp = TripartiteInputs {
            d,
            keys: &keys,
            vals: &vals,
            exact: &exact,
            centroids: &[],
            vsum: &[],
            sizes: &[],
            estimated: &[],
        };
        let mut out = vec![0.0; d];
        tripartite_attention(&q, &inp, &mut out);
        assert!(rel_err(&out, &full) < 1e-5);
    }

    #[test]
    fn tripartite_singleton_clusters_match_full() {
        // every token as its own estimated cluster == full attention
        let mut rng = Rng::new(7);
        let (d, t) = (8, 40);
        let keys = randvec(&mut rng, t * d);
        let vals = randvec(&mut rng, t * d);
        let q = randvec(&mut rng, d);
        let sizes = vec![1.0; t];
        let estimated: Vec<usize> = (0..t).collect();
        let inp = TripartiteInputs {
            d,
            keys: &keys,
            vals: &vals,
            exact: &[],
            centroids: &keys,
            vsum: &vals,
            sizes: &sizes,
            estimated: &estimated,
        };
        let mut out = vec![0.0; d];
        tripartite_attention(&q, &inp, &mut out);
        let mut full = vec![0.0; d];
        full_attention(&q, &keys, &vals, d, &mut full);
        assert!(rel_err(&out, &full) < 1e-5, "rel={}", rel_err(&out, &full));
    }

    #[test]
    fn tripartite_estimation_improves_over_dropping_tail() {
        // heavy head exact, clustered tail: including the estimation zone
        // must be closer to full attention than ignoring the tail.
        let mut rng = Rng::new(11);
        let (d, t) = (16, 256);
        let keys = randvec(&mut rng, t * d);
        let vals = randvec(&mut rng, t * d);
        let q = randvec(&mut rng, d);
        let mut full = vec![0.0; d];
        full_attention(&q, &keys, &vals, d, &mut full);

        // exact = top 32 by score; tail in 16-token clusters
        let w = attention_weights(&q, &keys, d);
        let mut order: Vec<usize> = (0..t).collect();
        order.sort_by(|&a, &b| w[b].partial_cmp(&w[a]).unwrap());
        let exact: Vec<usize> = order[..32].to_vec();
        let tail: Vec<usize> = order[32..].to_vec();
        let m = tail.len() / 16;
        let mut centroids = vec![0.0f32; m * d];
        let mut vsum = vec![0.0f32; m * d];
        let mut sizes = vec![0.0f32; m];
        for (ci, chunk) in tail.chunks(16).take(m).enumerate() {
            for &ti in chunk {
                axpy(1.0, &keys[ti * d..(ti + 1) * d], &mut centroids[ci * d..(ci + 1) * d]);
                axpy(1.0, &vals[ti * d..(ti + 1) * d], &mut vsum[ci * d..(ci + 1) * d]);
            }
            sizes[ci] = chunk.len() as f32;
            let inv = 1.0 / chunk.len() as f32;
            centroids[ci * d..(ci + 1) * d].iter_mut().for_each(|x| *x *= inv);
        }
        let estimated: Vec<usize> = (0..m).collect();
        let inp = TripartiteInputs {
            d, keys: &keys, vals: &vals, exact: &exact,
            centroids: &centroids, vsum: &vsum, sizes: &sizes, estimated: &estimated,
        };
        let mut with_est = vec![0.0; d];
        tripartite_attention(&q, &inp, &mut with_est);
        let mut no_est = vec![0.0; d];
        subset_attention(&q, &keys, &vals, d, &exact, &mut no_est);

        let c_est = cosine(&with_est, &full);
        let c_drop = cosine(&no_est, &full);
        assert!(
            c_est >= c_drop - 1e-6,
            "estimation should not hurt: {c_est} vs {c_drop}"
        );
    }
}
