//! Wave index — the paper's Attention-aWare VEctor index (§4.2).
//!
//! Per (layer, kv-head): KV vectors are partitioned into clusters by
//! segmented spherical k-means; cluster centroids + summed values + sizes
//! form the GPU-resident [`MetaIndex`]; the KV vectors themselves are
//! packed into CPU blocks ([`HeadStore`]). A query selects the tripartite
//! zones: steady (sink + local window, position-based), retrieval (top-r
//! clusters by centroid score, exact attention), estimation (next-e
//! clusters, accuracy-bound estimation via Eq. 2–4).

pub mod kmeans;
pub mod meta;

pub use kmeans::{spherical_kmeans, spherical_kmeans_pooled, Clustering};
pub use meta::MetaIndex;

use crate::attention::{tripartite_attention_with, MergeScratch, TripartiteInputs};
use crate::config::ZoneConfig;
use crate::kernels;
use crate::kvcache::prefix::{SealedBlockMeta, SealedCluster, SealedSlot};
use crate::kvcache::{
    append_snapshot_page, read_snapshot_page, AllocError, BlockArena, BlockData, BlockRef,
    HeadStore, SpillCandidate, SpillPolicy, TenantId, DEFAULT_TENANT,
};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// The zone decision for one query: which clusters are retrieved exactly
/// and which are estimated.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ZoneSelection {
    /// Cluster ids for exact attention (retrieval zone), best-first.
    pub retrieval: Vec<u32>,
    /// Cluster ids for accuracy-bound estimation (estimation zone).
    pub estimation: Vec<u32>,
}

impl ZoneSelection {
    pub fn is_empty(&self) -> bool {
        self.retrieval.is_empty() && self.estimation.is_empty()
    }
}

/// Reusable scratch for the selection hot path (zero alloc per step).
/// The `select_*_into` entry points write the zone decision into the
/// embedded [`ZoneSelection`] and hand back a borrow, so steady-state
/// selection reuses its buffers instead of allocating per call.
#[derive(Default)]
pub struct SelectScratch {
    scores: Vec<f32>,
    /// `[g, m]` per-query centroid scores from the GQA-batched gemm path
    /// (select_group_into with g > 1); reduced into `scores` by
    /// `group_max_reduce`.
    gm: Vec<f32>,
    order: Vec<u32>,
    sel: ZoneSelection,
}

impl SelectScratch {
    /// The zone selection produced by the most recent `select_*_into`.
    pub fn selection(&self) -> &ZoneSelection {
        &self.sel
    }
}

/// Reusable buffers for [`WaveIndex::attend_with`]: gathered exact-zone
/// KV, index lists, and the merge score scratch. One per decode task;
/// after warmup a decode step performs zero heap allocations.
#[derive(Default)]
pub struct DecodeScratch {
    merge: MergeScratch,
    ex_keys: Vec<f32>,
    ex_vals: Vec<f32>,
    exact_idx: Vec<usize>,
    est_idx: Vec<usize>,
}

/// Why a wave-index state snapshot could not be imported.
#[derive(Debug)]
pub enum SnapshotError {
    /// The byte stream is truncated, mis-framed, or internally
    /// inconsistent.
    Corrupt(&'static str),
    /// The snapshot's geometry does not match the target arena/config.
    Geometry { field: &'static str, want: usize, got: usize },
    /// The target arena refused a KV block mid-rebuild (every block the
    /// partial import checked out has been returned).
    Alloc(AllocError),
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::Corrupt(what) => write!(f, "corrupt snapshot: {what}"),
            SnapshotError::Geometry { field, want, got } => {
                write!(f, "snapshot geometry mismatch: {field} = {got}, target wants {want}")
            }
            SnapshotError::Alloc(e) => write!(f, "snapshot rebuild refused a block: {e:?}"),
        }
    }
}

impl std::error::Error for SnapshotError {}

impl From<AllocError> for SnapshotError {
    fn from(e: AllocError) -> Self {
        SnapshotError::Alloc(e)
    }
}

/// `b"WIDX"` — first four bytes of every wave-index state snapshot.
const SNAPSHOT_MAGIC: u32 = u32::from_le_bytes(*b"WIDX");
const SNAPSHOT_VERSION: u32 = 1;

/// Bounds-checked LE reader over a snapshot byte stream.
struct SnapCursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> SnapCursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        let end = self
            .off
            .checked_add(n)
            .ok_or(SnapshotError::Corrupt("offset overflow"))?;
        if end > self.buf.len() {
            return Err(SnapshotError::Corrupt("truncated stream"));
        }
        let s = &self.buf[self.off..end];
        self.off = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, SnapshotError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, SnapshotError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, SnapshotError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, SnapshotError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>, SnapshotError> {
        let raw = self.take(n.checked_mul(4).ok_or(SnapshotError::Corrupt("length overflow"))?)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u32_vec(&mut self, n: usize) -> Result<Vec<u32>, SnapshotError> {
        let raw = self.take(n.checked_mul(4).ok_or(SnapshotError::Corrupt("length overflow"))?)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// Tokens a partially-failed segment clustering could not place, handed
/// back (position order) so the caller can restore them to the pending
/// buffer — the token-partition invariant survives an arena refusal.
struct SegmentDrop {
    err: AllocError,
    keys: Vec<f32>,
    vals: Vec<f32>,
    pos: Vec<u32>,
}

/// Reusable gather buffers for segment clustering: k-means membership
/// lists plus the per-cluster key/value/position staging the store
/// allocates from. One instance threads through many
/// [`WaveIndex::try_feed_build_with`] calls (and through every index of
/// a chunked prefill), so a chunk that crosses a re-cluster boundary
/// reuses warmed capacity and a chunk that doesn't allocates nothing.
#[derive(Default)]
pub struct BuildScratch {
    members: Vec<Vec<u32>>,
    ck: Vec<f32>,
    cv: Vec<f32>,
    cp: Vec<u32>,
    vsum: Vec<f32>,
}

/// In-flight chunked-build cursor ([`WaveIndex::begin_build_in_for`]).
/// All zone boundaries are fixed up front from the declared total
/// length, so feeding the same tokens in any chunking clusters the same
/// segments with the same per-segment seeds — the finished index is
/// bit-identical to a monolithic [`WaveIndex::try_build_in_for`].
struct BuildProgress {
    /// Declared context length (the monolithic build's `n`).
    n_total: usize,
    /// End of the segmented-clustering region (`n_total - local`).
    mid_end: usize,
    /// First position of the sealed-prefix graft's tail (== sink when
    /// ungrafted); fed rows in `[sink, covered)` are already indexed by
    /// the attached shared clusters and are skipped.
    covered: usize,
    /// Next segment start position (advances as segments commit).
    next_start: usize,
    /// Rows fed so far (absolute position of the next expected row).
    fed: usize,
}

/// Per-head wave index.
pub struct WaveIndex {
    cfg: ZoneConfig,
    d: usize,
    /// CPU home of clustered KV vectors.
    store: HeadStore,
    /// GPU-resident representatives.
    meta: MetaIndex,
    /// Physical blocks per cluster (aligned with meta cluster ids).
    cluster_blocks: Vec<Vec<BlockRef>>,
    /// Steady zone, sink part: first `steady_sink` tokens.
    sink_keys: Vec<f32>,
    sink_vals: Vec<f32>,
    sink_pos: Vec<u32>,
    /// Steady zone, local part + pending update buffer (recent tokens not
    /// yet clustered). Oldest `update_segment` tokens are clustered once
    /// this exceeds `steady_local + update_segment`.
    pend_keys: Vec<f32>,
    pend_vals: Vec<f32>,
    pend_pos: Vec<u32>,
    /// Total tokens ever seen (context length).
    n_seen: usize,
    /// Number of incremental re-clusterings performed.
    n_updates: usize,
    seed: u64,
    /// Monotone selection counter (bumped by [`WaveIndex::note_selection`]).
    epoch: AtomicU64,
    /// Per-cluster last-retrieved epoch (0 = never retrieved) — the
    /// access metadata spill policies rank victims by. Atomics so the
    /// parallel assembly fan-out can record accesses through `&self`.
    access_epoch: Vec<AtomicU64>,
    /// Clusters the most recent selection wanted (retrieval +
    /// estimation): the estimator's picks for the *next* step, i.e. the
    /// engine's prefetch set.
    recent: Mutex<Vec<u32>>,
    /// With a policy armed, an append whose re-clustering would hit a
    /// full hot tier demotes this head's coldest clusters first
    /// (ArenaFull means "demote, then retry" before "defer").
    spill_policy: Option<Arc<dyn SpillPolicy>>,
    /// Accuracy bound for lossy cold storage: a cluster may be stored
    /// through a lossy spill codec only if the mean cosine of its
    /// member keys to its centroid is at least this floor (tight
    /// clusters ⇒ the estimation head's error bound absorbs the
    /// quantization noise). 1.0 disables lossy placement entirely.
    lossy_cos_floor: f32,
    /// `Some` while a chunked build is in flight
    /// ([`WaveIndex::begin_build_in_for`]); `None` once complete.
    build: Option<BuildProgress>,
}

impl WaveIndex {
    /// Build from a full prefill context `[n, d]` via segmented
    /// clustering, allocating KV blocks from a private arena (tests and
    /// standalone baselines; engine code shares one arena via
    /// [`WaveIndex::try_build_in_for`]).
    pub fn build(
        cfg: ZoneConfig,
        d: usize,
        block_bytes: usize,
        keys: &[f32],
        vals: &[f32],
        seed: u64,
    ) -> Self {
        Self::build_in(&BlockArena::shared(d, block_bytes), cfg, keys, vals, seed)
    }

    /// Build from a full prefill context `[n, d]`, checking KV blocks
    /// out of the shared engine arena (paper §4.3: storage is a pooled
    /// engine resource, not per-session memory). Panics if the arena
    /// refuses a block — capped arenas use
    /// [`WaveIndex::try_build_in_for`], which reports a typed error.
    pub fn build_in(
        arena: &Arc<BlockArena>,
        cfg: ZoneConfig,
        keys: &[f32],
        vals: &[f32],
        seed: u64,
    ) -> Self {
        Self::try_build_in_for(arena, DEFAULT_TENANT, cfg, keys, vals, seed)
            .expect("wave index build refused a KV block — capped arenas use try_build_in_for")
    }

    /// Fallible, tenant-attributed build (the serving path under arena
    /// capacity caps). On failure every block the partial build checked
    /// out is returned to the arena — the caller sees an unchanged pool.
    pub fn try_build_in_for(
        arena: &Arc<BlockArena>,
        tenant: TenantId,
        cfg: ZoneConfig,
        keys: &[f32],
        vals: &[f32],
        seed: u64,
    ) -> Result<Self, AllocError> {
        Self::build_with_graft(arena, tenant, cfg, None, keys, vals, seed)
    }

    /// Grafted build (DESIGN.md §2 "Prefix sharing & CoW"): the first
    /// `covered` tokens come from a sealed prefix — their clusters
    /// (centroids, value sums, positions) attach as shared, refcounted
    /// block views with no recomputation and no fresh checkouts — and
    /// the private tail clusters/pends exactly like a fresh build. With
    /// the same content-derived `seed` the result is bit-identical to
    /// an unshared build of the same tokens (property-tested in
    /// `rust/tests/sharing.rs`); only block ids and residency differ.
    pub fn try_build_grafted_in_for(
        arena: &Arc<BlockArena>,
        tenant: TenantId,
        cfg: ZoneConfig,
        sealed: &SealedSlot,
        covered: usize,
        keys: &[f32],
        vals: &[f32],
        seed: u64,
    ) -> Result<Self, AllocError> {
        Self::build_with_graft(arena, tenant, cfg, Some((sealed, covered)), keys, vals, seed)
    }

    fn build_with_graft(
        arena: &Arc<BlockArena>,
        tenant: TenantId,
        cfg: ZoneConfig,
        graft: Option<(&SealedSlot, usize)>,
        keys: &[f32],
        vals: &[f32],
        seed: u64,
    ) -> Result<Self, AllocError> {
        let d = arena.d();
        let n = keys.len() / d;
        assert_eq!(keys.len(), vals.len());
        // The monolithic build is one maximal chunk through the
        // incremental builder — chunked prefill is bit-identical to this
        // path by construction, not by parallel maintenance.
        let mut idx = Self::begin_build_with_graft(arena, tenant, cfg, graft, n, seed);
        // On failure `idx` drops here and its HeadStore returns every
        // block already checked out — a failed build leaves no residue.
        idx.try_feed_build_with(keys, vals, &mut BuildScratch::default())?;
        debug_assert!(idx.build.is_none(), "single-chunk build left a cursor behind");
        Ok(idx)
    }

    /// Open a chunked build that will be fed `n_total` tokens through
    /// [`WaveIndex::try_feed_build_with`]. Zone boundaries (sink, local
    /// window, segment starts — and therefore every per-segment k-means
    /// seed) are fixed here from `n_total`, so any chunking of the same
    /// token stream produces a bit-identical finished index.
    pub fn begin_build_in_for(
        arena: &Arc<BlockArena>,
        tenant: TenantId,
        cfg: ZoneConfig,
        n_total: usize,
        seed: u64,
    ) -> Self {
        Self::begin_build_with_graft(arena, tenant, cfg, None, n_total, seed)
    }

    /// Chunked-build variant of [`WaveIndex::try_build_grafted_in_for`]:
    /// the sealed prefix attaches up front; fed rows inside the covered
    /// range are skipped (their clusters are already resident).
    pub fn begin_build_grafted_in_for(
        arena: &Arc<BlockArena>,
        tenant: TenantId,
        cfg: ZoneConfig,
        sealed: &SealedSlot,
        covered: usize,
        n_total: usize,
        seed: u64,
    ) -> Self {
        Self::begin_build_with_graft(arena, tenant, cfg, Some((sealed, covered)), n_total, seed)
    }

    fn begin_build_with_graft(
        arena: &Arc<BlockArena>,
        tenant: TenantId,
        cfg: ZoneConfig,
        graft: Option<(&SealedSlot, usize)>,
        n_total: usize,
        seed: u64,
    ) -> Self {
        let d = arena.d();
        let mut idx = WaveIndex {
            cfg,
            d,
            store: HeadStore::new_in_for(Arc::clone(arena), tenant),
            meta: MetaIndex::new(d),
            cluster_blocks: Vec::new(),
            sink_keys: Vec::new(),
            sink_vals: Vec::new(),
            sink_pos: Vec::new(),
            pend_keys: Vec::new(),
            pend_vals: Vec::new(),
            pend_pos: Vec::new(),
            n_seen: 0,
            n_updates: 0,
            seed,
            epoch: AtomicU64::new(0),
            access_epoch: Vec::new(),
            recent: Mutex::new(Vec::new()),
            spill_policy: None,
            lossy_cos_floor: 0.5,
            build: None,
        };
        // Sink tokens stay out of the index (position-based steady zone);
        // the local window (and any residue shorter than a segment) pends.
        let sink = idx.cfg.steady_sink.min(n_total);
        let local = idx.cfg.steady_local.min(n_total - sink);
        let mid_end = n_total - local;

        // Sealed prefix: attach shared clusters instead of re-clustering.
        let mut start = sink;
        if let Some((sealed, covered)) = graft {
            assert!(covered >= sink && covered <= mid_end, "graft coverage out of range");
            for sc in &sealed.clusters {
                debug_assert!(
                    sc.pos.iter().all(|&p| (p as usize) < covered),
                    "sealed cluster outside its prefix"
                );
                let mut refs = Vec::with_capacity(sc.blocks.len());
                for b in &sc.blocks {
                    // On failure `idx` drops and releases every shared
                    // reference already taken — no residue.
                    let r = idx
                        .store
                        .attach_shared(b.id, b.len)
                        .expect("sealed prefix block vanished from the arena");
                    refs.push(r);
                }
                let id = idx.meta.push(&sc.centroid, &sc.vsum, sc.pos.clone());
                debug_assert_eq!(id, idx.cluster_blocks.len());
                idx.cluster_blocks.push(refs);
                idx.access_epoch.push(AtomicU64::new(0));
            }
            start = covered;
        }
        // Pre-size the pending buffer for its in-build high-water mark
        // (one nearly-complete segment plus the local window) so warm
        // feed chunks append without growing.
        let reserve = (idx.cfg.build_segment + idx.cfg.steady_local).min(n_total);
        idx.pend_keys.reserve(reserve * d);
        idx.pend_vals.reserve(reserve * d);
        idx.pend_pos.reserve(reserve);
        idx.build =
            Some(BuildProgress { n_total, mid_end, covered: start, next_start: start, fed: 0 });
        idx
    }

    /// Whether a chunked build is still in flight (more rows expected,
    /// or a refused segment awaiting retry).
    pub fn build_in_progress(&self) -> bool {
        self.build.is_some()
    }

    /// Rows a chunked build still expects (0 once every declared token
    /// has been fed, even if a refused segment is still pending retry).
    pub fn build_remaining(&self) -> usize {
        self.build.as_ref().map_or(0, |b| b.n_total - b.fed)
    }

    /// Feed the next chunk of context rows (`[n, d]`, positions
    /// following on from the previous chunk) into an open chunked
    /// build, clustering every segment that becomes complete. See
    /// [`WaveIndex::try_feed_build_with`].
    pub fn try_feed_build(&mut self, keys: &[f32], vals: &[f32]) -> Result<(), AllocError> {
        self.try_feed_build_with(keys, vals, &mut BuildScratch::default())
    }

    /// Feed the next chunk of an open chunked build, reusing `scratch`
    /// for any segment clustering it triggers. Rows land in the sink /
    /// grafted / pending region their absolute position dictates, then
    /// every fully-fed segment clusters exactly as the monolithic build
    /// would (same boundaries, same seeds). An empty chunk is legal and
    /// just retries pending work.
    ///
    /// On an arena refusal mid-segment the unplaced tokens return to
    /// the pending buffer and the cursor stays put: the build remains
    /// resumable, and the next call (empty or not) retries the segment
    /// once the caller has reclaimed space. The final chunk (cursor
    /// complete, every segment committed) closes the build; the index
    /// is then bit-identical to [`WaveIndex::try_build_in_for`] over
    /// the concatenated chunks.
    pub fn try_feed_build_with(
        &mut self,
        keys: &[f32],
        vals: &[f32],
        scratch: &mut BuildScratch,
    ) -> Result<(), AllocError> {
        let d = self.d;
        assert_eq!(keys.len(), vals.len());
        let n = keys.len() / d;
        debug_assert_eq!(keys.len(), n * d);
        let bp = self.build.as_ref().expect("no chunked build in progress");
        let (n_total, covered, fed) = (bp.n_total, bp.covered, bp.fed);
        assert!(fed + n <= n_total, "chunked build fed past its declared length");
        let sink = self.cfg.steady_sink.min(n_total);
        let (start_pos, end_pos) = (fed, fed + n);
        if start_pos < sink {
            let take = sink.min(end_pos) - start_pos;
            self.sink_keys.extend_from_slice(&keys[..take * d]);
            self.sink_vals.extend_from_slice(&vals[..take * d]);
            self.sink_pos.extend(start_pos as u32..(start_pos + take) as u32);
        }
        // Rows in [sink, covered) are already served by the grafted
        // prefix; everything after pends until its segment completes.
        let p0 = covered.max(start_pos.min(end_pos));
        if end_pos > p0 {
            let off = (p0 - start_pos) * d;
            self.pend_keys.extend_from_slice(&keys[off..]);
            self.pend_vals.extend_from_slice(&vals[off..]);
            self.pend_pos.extend(p0 as u32..end_pos as u32);
        }
        self.build.as_mut().unwrap().fed = end_pos;
        self.n_seen = end_pos;
        self.drain_build_segments(scratch)
    }

    /// Cluster every fully-fed segment of an open chunked build, then
    /// close the build if the whole declared context has been fed.
    fn drain_build_segments(&mut self, scratch: &mut BuildScratch) -> Result<(), AllocError> {
        loop {
            let bp = self.build.as_ref().expect("no chunked build in progress");
            let (next_start, mid_end, fed, n_total) =
                (bp.next_start, bp.mid_end, bp.fed, bp.n_total);
            if next_start < mid_end {
                let seg = (mid_end - next_start).min(self.cfg.build_segment);
                // Avoid a tiny trailing segment: fold < cluster-size
                // remainders into the pending buffer rather than
                // clustering noise (the monolithic build's break).
                if seg >= self.cfg.tokens_per_cluster {
                    if fed < next_start + seg {
                        // segment not fully fed yet: wait for more rows
                        return Ok(());
                    }
                    // Tiered arena: make hot room for the segment up
                    // front — full hot tier means "demote, then retry",
                    // not "fail".
                    self.make_hot_room(seg);
                    let d = self.d;
                    let keys: Vec<f32> = self.pend_keys.drain(..seg * d).collect();
                    let vals: Vec<f32> = self.pend_vals.drain(..seg * d).collect();
                    let pos: Vec<u32> = self.pend_pos.drain(..seg).collect();
                    debug_assert_eq!(pos[0] as usize, next_start);
                    match self.try_cluster_segment_with(&keys, &vals, &pos, scratch) {
                        Ok(()) => {
                            self.build.as_mut().unwrap().next_start += seg;
                            continue;
                        }
                        Err(sd) => {
                            // un-drain the unplaced tokens (oldest first):
                            // the cursor stays put, a later feed retries
                            self.pend_keys.splice(0..0, sd.keys);
                            self.pend_vals.splice(0..0, sd.vals);
                            self.pend_pos.splice(0..0, sd.pos);
                            return Err(sd.err);
                        }
                    }
                }
            }
            // No further segment can ever cluster; the remainder + local
            // window stay pending. Close once everything has been fed.
            if fed == n_total {
                self.build = None;
            }
            return Ok(());
        }
    }

    /// Seal every cluster lying entirely inside the first `covered`
    /// tokens into shared, refcounted blocks and return the metadata a
    /// grafting session needs ([`SealedSlot`]). This index keeps
    /// serving the (now shared, read-only) blocks; already-shared
    /// clusters — from an earlier graft — are re-described without
    /// re-sealing. Clusters with any cold block stop the scan (sealing
    /// is prefix-contiguous by construction).
    pub fn seal_prefix(&mut self, covered: usize) -> SealedSlot {
        let mut out = SealedSlot::default();
        for c in 0..self.cluster_blocks.len() {
            let pos = self.meta.cluster_tokens(c);
            if pos.iter().any(|&p| p as usize >= covered) {
                break;
            }
            let refs: Vec<BlockRef> = self.cluster_blocks[c].clone();
            if refs.iter().any(|r| !self.store.is_hot(*r)) {
                break;
            }
            let mut blocks = Vec::with_capacity(refs.len());
            for r in refs {
                let ok = self.store.seal_block(r);
                debug_assert!(ok, "hot block must seal");
                blocks.push(SealedBlockMeta { id: r.block, len: r.len });
            }
            out.clusters.push(SealedCluster {
                centroid: self.meta.centroid(c).to_vec(),
                vsum: self.meta.vsum_flat()[c * self.d..(c + 1) * self.d].to_vec(),
                pos: pos.to_vec(),
                blocks,
            });
        }
        out
    }

    /// Serialize this index's full logical state — cluster metadata
    /// (centroid, value sum, token positions, lossy clearance), every
    /// cluster's KV through the bit-exact snapshot page format
    /// (cold/compressed blocks read back through their codec first),
    /// sink and pending KV, and the clustering identity
    /// (`seed`/`n_seen`/`n_updates`) — into an LE byte stream that
    /// [`WaveIndex::import_state`] rebuilds on another replica. Derived
    /// state is deliberately absent: wave-buffer cache contents, access
    /// epochs, and hot/cold residency affect performance, never token
    /// bits, so the target starts them fresh. The `ZoneConfig` is also
    /// not carried — replicas of one deployment share it, and the seed
    /// is what keeps future segment re-clusterings bit-identical.
    pub fn export_state(&self) -> Vec<u8> {
        assert!(self.build.is_none(), "cannot snapshot a mid-build index");
        let d = self.d;
        let tpb = self.store.tokens_per_block();
        let m = self.cluster_blocks.len();
        let mut out = Vec::new();
        out.extend_from_slice(&SNAPSHOT_MAGIC.to_le_bytes());
        out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
        out.extend_from_slice(&(d as u32).to_le_bytes());
        out.extend_from_slice(&(tpb as u32).to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&(self.n_seen as u64).to_le_bytes());
        out.extend_from_slice(&(self.n_updates as u64).to_le_bytes());
        out.extend_from_slice(&self.lossy_cos_floor.to_le_bytes());
        out.extend_from_slice(&(m as u32).to_le_bytes());
        out.extend_from_slice(&(self.sink_pos.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.pend_pos.len() as u32).to_le_bytes());
        let (mut bk, mut bv) = (Vec::new(), Vec::new());
        for c in 0..m {
            let pos = self.meta.cluster_tokens(c);
            out.extend_from_slice(&(pos.len() as u32).to_le_bytes());
            out.push(self.cluster_lossy_ok(c as u32) as u8);
            for x in self.meta.centroid(c) {
                out.extend_from_slice(&x.to_le_bytes());
            }
            for x in &self.meta.vsum_flat()[c * d..(c + 1) * d] {
                out.extend_from_slice(&x.to_le_bytes());
            }
            for p in pos {
                out.extend_from_slice(&p.to_le_bytes());
            }
            let refs = &self.cluster_blocks[c];
            out.extend_from_slice(&(refs.len() as u32).to_le_bytes());
            let mut tok = 0usize;
            for r in refs {
                bk.clear();
                bv.clear();
                self.store.copy_block_kv(*r, &mut bk, &mut bv);
                let len = r.len as usize;
                debug_assert_eq!(bk.len(), len * d);
                let mut data = BlockData::zeroed(tpb, d);
                data.keys[..len * d].copy_from_slice(&bk);
                data.vals[..len * d].copy_from_slice(&bv);
                data.pos[..len].copy_from_slice(&pos[tok..tok + len]);
                append_snapshot_page(&data, len, tpb, d, &mut out);
                tok += len;
            }
            debug_assert_eq!(tok, pos.len(), "cluster blocks out of step with meta");
        }
        for x in self.sink_keys.iter().chain(&self.sink_vals) {
            out.extend_from_slice(&x.to_le_bytes());
        }
        for p in &self.sink_pos {
            out.extend_from_slice(&p.to_le_bytes());
        }
        for x in self.pend_keys.iter().chain(&self.pend_vals) {
            out.extend_from_slice(&x.to_le_bytes());
        }
        for p in &self.pend_pos {
            out.extend_from_slice(&p.to_le_bytes());
        }
        out
    }

    /// Rebuild an index from an [`WaveIndex::export_state`] stream,
    /// checking fresh KV blocks out of `arena` on behalf of `tenant`.
    /// Cluster ids, token partition, centroids, value sums, and every
    /// f32 bit of KV match the source exactly; only block ids and tier
    /// residency differ (every imported block starts hot and private).
    /// The source and target block strides may differ — pages re-pack
    /// into the target's geometry. Fails soft on corrupt bytes, a head
    /// dimension mismatch, or an arena refusal; a failed import leaves
    /// the arena unchanged.
    pub fn import_state(
        arena: &Arc<BlockArena>,
        tenant: TenantId,
        cfg: ZoneConfig,
        bytes: &[u8],
    ) -> Result<WaveIndex, SnapshotError> {
        let mut cur = SnapCursor { buf: bytes, off: 0 };
        if cur.u32()? != SNAPSHOT_MAGIC {
            return Err(SnapshotError::Corrupt("bad magic"));
        }
        if cur.u32()? != SNAPSHOT_VERSION {
            return Err(SnapshotError::Corrupt("unknown snapshot version"));
        }
        let d = cur.u32()? as usize;
        if d != arena.d() {
            return Err(SnapshotError::Geometry { field: "d", want: arena.d(), got: d });
        }
        let src_tpb = cur.u32()? as usize;
        if src_tpb == 0 {
            return Err(SnapshotError::Corrupt("zero block stride"));
        }
        let seed = cur.u64()?;
        let n_seen = cur.u64()? as usize;
        let n_updates = cur.u64()? as usize;
        let lossy_cos_floor = cur.f32()?;
        let m = cur.u32()? as usize;
        let sink_len = cur.u32()? as usize;
        let pend_len = cur.u32()? as usize;
        let mut idx = WaveIndex {
            cfg,
            d,
            store: HeadStore::new_in_for(Arc::clone(arena), tenant),
            meta: MetaIndex::new(d),
            cluster_blocks: Vec::new(),
            sink_keys: Vec::new(),
            sink_vals: Vec::new(),
            sink_pos: Vec::new(),
            pend_keys: Vec::new(),
            pend_vals: Vec::new(),
            pend_pos: Vec::new(),
            n_seen: 0,
            n_updates: 0,
            seed,
            epoch: AtomicU64::new(0),
            access_epoch: Vec::new(),
            recent: Mutex::new(Vec::new()),
            spill_policy: None,
            lossy_cos_floor,
            build: None,
        };
        let mut page = BlockData::zeroed(src_tpb, d);
        let (mut ck, mut cv) = (Vec::new(), Vec::new());
        for _ in 0..m {
            let n_tok = cur.u32()? as usize;
            let _flags = cur.u8()?;
            let centroid = cur.f32_vec(d)?;
            let vsum = cur.f32_vec(d)?;
            let pos = cur.u32_vec(n_tok)?;
            let n_pages = cur.u32()? as usize;
            ck.clear();
            cv.clear();
            let mut tok = 0usize;
            for _ in 0..n_pages {
                let (valid, next) = read_snapshot_page(bytes, cur.off, src_tpb, d, &mut page)
                    .ok_or(SnapshotError::Corrupt("bad snapshot page"))?;
                cur.off = next;
                if tok + valid > n_tok {
                    return Err(SnapshotError::Corrupt("cluster pages overflow token count"));
                }
                ck.extend_from_slice(&page.keys[..valid * d]);
                cv.extend_from_slice(&page.vals[..valid * d]);
                if page.pos[..valid] != pos[tok..tok + valid] {
                    return Err(SnapshotError::Corrupt("page positions disagree with meta"));
                }
                tok += valid;
            }
            if tok != n_tok {
                return Err(SnapshotError::Corrupt("cluster token count mismatch"));
            }
            // On failure `idx` drops here and its HeadStore returns
            // every block already checked out — no residue.
            let refs = idx.store.try_alloc_cluster(&ck, &cv, &pos)?;
            let id = idx.meta.push(&centroid, &vsum, pos);
            debug_assert_eq!(id, idx.cluster_blocks.len());
            idx.cluster_blocks.push(refs);
            idx.access_epoch.push(AtomicU64::new(0));
        }
        idx.sink_keys = cur.f32_vec(sink_len * d)?;
        idx.sink_vals = cur.f32_vec(sink_len * d)?;
        idx.sink_pos = cur.u32_vec(sink_len)?;
        idx.pend_keys = cur.f32_vec(pend_len * d)?;
        idx.pend_vals = cur.f32_vec(pend_len * d)?;
        idx.pend_pos = cur.u32_vec(pend_len)?;
        if cur.off != bytes.len() {
            return Err(SnapshotError::Corrupt("trailing bytes"));
        }
        idx.n_seen = n_seen;
        idx.n_updates = n_updates;
        Ok(idx)
    }

    /// Tokens covered by committed clusters from position 0 (sink +
    /// clustered segments; the pending tail starts here). This is the
    /// ceiling on what [`WaveIndex::seal_prefix`] can seal.
    pub fn clustered_prefix_tokens(&self) -> usize {
        self.n_seen - self.pend_pos.len()
    }

    /// Shared (refcounted) blocks this index currently serves.
    pub fn n_shared_blocks(&self) -> usize {
        self.store.n_shared_blocks()
    }

    /// Cluster one segment (`pos[i]` is token i's context position) and
    /// append its clusters to meta + store. On an arena refusal the
    /// tokens of the failed cluster and of every not-yet-committed
    /// cluster come back in the error (position order) so the caller can
    /// restore them; already-committed clusters stay indexed, keeping
    /// the token partition intact.
    fn try_cluster_segment(
        &mut self,
        keys: &[f32],
        vals: &[f32],
        pos: &[u32],
    ) -> Result<(), SegmentDrop> {
        self.try_cluster_segment_with(keys, vals, pos, &mut BuildScratch::default())
    }

    fn try_cluster_segment_with(
        &mut self,
        keys: &[f32],
        vals: &[f32],
        pos: &[u32],
        scratch: &mut BuildScratch,
    ) -> Result<(), SegmentDrop> {
        let d = self.d;
        let n = pos.len();
        debug_assert_eq!(keys.len(), n * d);
        let k = self.cfg.clusters_for_segment(n);
        let cl = spherical_kmeans(
            keys,
            d,
            k,
            self.cfg.kmeans_iters,
            self.cfg.centering,
            self.seed ^ (pos[0] as u64).wrapping_mul(0x9e3779b1),
        );
        // Gather members per cluster, preserving context order.
        let BuildScratch { members, ck, cv, cp, vsum } = scratch;
        for m in members.iter_mut() {
            m.clear();
        }
        if members.len() < cl.k {
            members.resize_with(cl.k, Vec::new);
        }
        for (i, &a) in cl.assign.iter().enumerate() {
            members[a as usize].push(i as u32);
        }
        for ci in 0..cl.k {
            if members[ci].is_empty() {
                continue;
            }
            ck.clear();
            cv.clear();
            cp.clear();
            vsum.clear();
            vsum.resize(d, 0.0);
            for &i in &members[ci] {
                let i = i as usize;
                ck.extend_from_slice(&keys[i * d..(i + 1) * d]);
                cv.extend_from_slice(&vals[i * d..(i + 1) * d]);
                cp.push(pos[i]);
                for j in 0..d {
                    vsum[j] += vals[i * d + j];
                }
            }
            match self.store.try_alloc_cluster(ck, cv, cp) {
                Ok(refs) => {
                    let id =
                        self.meta.push(&cl.centroids[ci * d..(ci + 1) * d], vsum, cp.clone());
                    debug_assert_eq!(id, self.cluster_blocks.len());
                    self.cluster_blocks.push(refs);
                    self.access_epoch.push(AtomicU64::new(0));
                }
                Err(err) => {
                    // hand the failed + remaining clusters' tokens back,
                    // oldest (lowest position) first
                    let mut rest: Vec<u32> =
                        members[ci..].iter().flat_map(|m| m.iter().copied()).collect();
                    rest.sort_unstable();
                    let mut rk = Vec::with_capacity(rest.len() * d);
                    let mut rv = Vec::with_capacity(rest.len() * d);
                    let mut rp = Vec::with_capacity(rest.len());
                    for &i in &rest {
                        let i = i as usize;
                        rk.extend_from_slice(&keys[i * d..(i + 1) * d]);
                        rv.extend_from_slice(&vals[i * d..(i + 1) * d]);
                        rp.push(pos[i]);
                    }
                    return Err(SegmentDrop { err, keys: rk, vals: rv, pos: rp });
                }
            }
        }
        Ok(())
    }

    /// Append one decoded token (paper §4.2 "Lightweight Index Updates").
    /// Panics if the arena refuses a block — capped serving paths use
    /// [`WaveIndex::try_append`].
    pub fn append(&mut self, key: &[f32], val: &[f32]) {
        self.try_append(key, val)
            .expect("wave index append refused a KV block — capped arenas use try_append")
    }

    /// Fallible append: re-clusters the oldest `update_segment` pending
    /// tokens once the pending buffer exceeds `steady_local +
    /// update_segment`. If the arena refuses a block mid-re-clustering,
    /// the not-yet-committed tokens return to the pending buffer — no
    /// token is ever lost — and the re-clustering retries on a later
    /// append once reclamation frees space.
    pub fn try_append(&mut self, key: &[f32], val: &[f32]) -> Result<(), AllocError> {
        debug_assert_eq!(key.len(), self.d);
        if self.n_seen < self.cfg.steady_sink {
            self.sink_keys.extend_from_slice(key);
            self.sink_vals.extend_from_slice(val);
            self.sink_pos.push(self.n_seen as u32);
            self.n_seen += 1;
            return Ok(());
        }
        self.pend_keys.extend_from_slice(key);
        self.pend_vals.extend_from_slice(val);
        self.pend_pos.push(self.n_seen as u32);
        self.n_seen += 1;

        let seg = self.cfg.update_segment;
        if self.pend_pos.len() >= self.cfg.steady_local + seg {
            // Tiered arena: make hot room for the re-clustering up
            // front by demoting this head's coldest clusters — a full
            // hot tier means "demote, then retry", not "fail".
            self.make_hot_room(seg);
            let d = self.d;
            // Split off the oldest segment.
            let keys: Vec<f32> = self.pend_keys.drain(..seg * d).collect();
            let vals: Vec<f32> = self.pend_vals.drain(..seg * d).collect();
            let pos: Vec<u32> = self.pend_pos.drain(..seg).collect();
            match self.try_cluster_segment(&keys, &vals, &pos) {
                Ok(()) => self.n_updates += 1,
                Err(sd) => {
                    // un-drain the unplaced tokens (oldest first) so the
                    // steady zone still covers them exactly
                    self.pend_keys.splice(0..0, sd.keys);
                    self.pend_vals.splice(0..0, sd.vals);
                    self.pend_pos.splice(0..0, sd.pos);
                    return Err(sd.err);
                }
            }
        }
        Ok(())
    }

    /// Demote this head's coldest clusters until the arena has hot
    /// headroom for a `seg`-token segment build (no-op without a spill
    /// policy or a capacity cap).
    fn make_hot_room(&mut self, seg: usize) {
        let Some(policy) = self.spill_policy.clone() else {
            return;
        };
        let (tpb, live, cap) = {
            let a = self.store.arena();
            (a.tokens_per_block(), a.live_blocks(), a.capacity_blocks())
        };
        let Some(cap) = cap else {
            return;
        };
        // worst case: every cluster of the segment adds a partial tail
        // block on top of the dense packing
        let need = seg.div_ceil(tpb) + self.cfg.clusters_for_segment(seg);
        let headroom = cap.saturating_sub(live);
        if headroom < need {
            self.demote_until(policy.as_ref(), need - headroom);
        }
    }

    /// Arm (or disarm) index-level demote-then-retry against the given
    /// spill policy. The engine sets this on every session index when
    /// cold-tier spill is enabled.
    pub fn set_spill_policy(&mut self, policy: Option<Arc<dyn SpillPolicy>>) {
        self.spill_policy = policy;
    }

    /// Record a selection for the spill machinery: bumps the epoch,
    /// stamps the retrieved clusters' access metadata, and publishes
    /// the wanted set ([`WaveIndex::recent_clusters`]) the engine
    /// prefetches for the next step: the retrieval zone plus the
    /// estimator's top picks (the estimation zone head — bounded, so a
    /// config that estimates *every* cluster cannot turn prefetch into
    /// a full-arena sweep each step). `&self` + atomics so the parallel
    /// assembly fan-out can call it.
    pub fn note_selection(&self, sel: &ZoneSelection) {
        let e = self.epoch.fetch_add(1, Ordering::Relaxed) + 1;
        for &c in &sel.retrieval {
            self.access_epoch[c as usize].store(e, Ordering::Relaxed);
        }
        let mut recent = self.recent.lock().unwrap();
        recent.clear();
        recent.extend_from_slice(&sel.retrieval);
        let cap_e = sel.retrieval.len().max(4);
        recent.extend(sel.estimation.iter().take(cap_e).copied());
    }

    /// Clusters the most recent selection wanted (the prefetch set).
    pub fn recent_clusters(&self) -> Vec<u32> {
        self.recent.lock().unwrap().clone()
    }

    /// Selection epoch a cluster was last retrieved at (0 = never).
    pub fn cluster_last_access(&self, c: u32) -> u64 {
        self.access_epoch[c as usize].load(Ordering::Relaxed)
    }

    /// Selections recorded so far.
    pub fn selection_epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Whether every block of a cluster is hot.
    pub fn cluster_is_hot(&self, c: u32) -> bool {
        self.cluster_blocks[c as usize].iter().all(|r| self.store.is_hot(*r))
    }

    /// Hot blocks a cluster currently holds.
    pub fn cluster_hot_blocks(&self, c: u32) -> usize {
        self.cluster_blocks[c as usize].iter().filter(|r| self.store.is_hot(**r)).count()
    }

    /// Demote every hot block of cluster `c` into the cold tier with
    /// the exact codec (bit-identical round-trip); returns how many
    /// blocks were demoted.
    pub fn demote_cluster(&mut self, c: u32) -> usize {
        self.demote_cluster_with(c, false)
    }

    /// Demote every hot block of cluster `c`, marking its pages
    /// lossy-eligible when the estimation head cleared the cluster
    /// (`lossy_ok` — see [`WaveIndex::cluster_lossy_ok`]). The spill
    /// store applies its configured codec only to eligible pages.
    pub fn demote_cluster_with(&mut self, c: u32, lossy_ok: bool) -> usize {
        let refs: Vec<BlockRef> = self.cluster_blocks[c as usize].clone();
        let mut n = 0;
        for r in refs {
            if self.store.demote_block_with(r, lossy_ok) {
                n += 1;
            }
        }
        n
    }

    /// Set the accuracy bound for lossy cold placement (mean member-key
    /// cosine to centroid a cluster must clear; 1.0 forbids lossy
    /// storage outright).
    pub fn set_lossy_cos_floor(&mut self, floor: f32) {
        self.lossy_cos_floor = floor;
    }

    /// Whether the estimation head clears cluster `c` for lossy cold
    /// storage. Two rules, both required:
    ///
    /// * positional — no token of the cluster may sit in the steady
    ///   zone: sink positions (`< steady_sink`) and the trailing local
    ///   window (`>= n_seen - steady_local`) are always stored exact
    ///   (they are attended every step, so quantization noise there is
    ///   unamortized);
    /// * dispersion — the mean cosine of member keys to the cluster
    ///   centroid must reach `lossy_cos_floor`: the estimator's Eq. 3
    ///   error bound tightens with intra-cluster coherence, so only
    ///   tight clusters can absorb direction-quantization noise inside
    ///   the bound.
    ///
    /// Conservative on any degenerate input (empty cluster, zero-norm
    /// centroid or keys): not cleared ⇒ stored exact.
    pub fn cluster_lossy_ok(&self, c: u32) -> bool {
        if self.lossy_cos_floor >= 1.0 {
            return false;
        }
        let pos = self.meta.cluster_tokens(c as usize);
        if pos.is_empty() {
            return false;
        }
        let min = *pos.iter().min().unwrap() as usize;
        let max = *pos.iter().max().unwrap() as usize;
        if min < self.cfg.steady_sink || max + self.cfg.steady_local >= self.n_seen {
            return false;
        }
        let cent = self.meta.centroid(c as usize);
        let cn = cent.iter().map(|x| x * x).sum::<f32>().sqrt();
        if !(cn > 0.0) {
            return false;
        }
        let (mut keys, mut vals) = (Vec::new(), Vec::new());
        for r in &self.cluster_blocks[c as usize] {
            // reads through the spill tier for already-cold members (a
            // partially promoted cluster must not regress to exact on
            // re-demotion); the bool only reports hot vs cold
            self.store.copy_block_kv(*r, &mut keys, &mut vals);
        }
        let d = self.d;
        let n = keys.len() / d;
        if n == 0 {
            return false;
        }
        let mut mean_cos = 0.0f32;
        for t in 0..n {
            let k = &keys[t * d..(t + 1) * d];
            let dot: f32 = k.iter().zip(cent).map(|(a, b)| a * b).sum();
            let kn = k.iter().map(|x| x * x).sum::<f32>().sqrt();
            if kn > 0.0 {
                mean_cos += dot / (kn * cn);
            }
        }
        mean_cos /= n as f32;
        mean_cos >= self.lossy_cos_floor
    }

    /// Promote every cold block of cluster `c` back into the hot tier.
    /// Returns `(promoted, staged, err)`: blocks promoted by this call,
    /// how many were served from the async-prefetch stage, and the
    /// refusal that stopped a partial promotion (already-promoted
    /// blocks stay hot — a later retry resumes where this one stopped).
    pub fn promote_cluster(&mut self, c: u32) -> (usize, usize, Option<AllocError>) {
        let refs: Vec<BlockRef> = self.cluster_blocks[c as usize].clone();
        let (mut n, mut staged) = (0, 0);
        for r in refs {
            match self.store.promote_block(r) {
                Ok(Some(s)) => {
                    n += 1;
                    if s {
                        staged += 1;
                    }
                }
                Ok(None) => {}
                Err(e) => return (n, staged, Some(e)),
            }
        }
        (n, staged, None)
    }

    /// Policy-driven demotion: rank this head's clusters with hot
    /// blocks by the spill policy (coldest first under the default) and
    /// demote from the front until at least `need_blocks` hot blocks
    /// were freed or nothing demotable remains. Returns the freed count
    /// and the demoted cluster ids (so callers can invalidate derived
    /// GPU-cache copies).
    pub fn demote_until(
        &mut self,
        policy: &dyn SpillPolicy,
        need_blocks: usize,
    ) -> (usize, Vec<u32>) {
        let mut cands: Vec<SpillCandidate> = Vec::new();
        for c in 0..self.cluster_blocks.len() {
            let hot = self.cluster_hot_blocks(c as u32);
            if hot == 0 {
                continue;
            }
            cands.push(SpillCandidate {
                cluster: c as u32,
                last_access: self.access_epoch[c].load(Ordering::Relaxed),
                hot_blocks: hot,
                lossy_ok: self.cluster_lossy_ok(c as u32),
            });
        }
        policy.order(&mut cands);
        let mut freed = 0;
        let mut demoted = Vec::new();
        for cand in cands {
            if freed >= need_blocks {
                break;
            }
            let n = self.demote_cluster_with(cand.cluster, cand.lossy_ok);
            if n > 0 {
                freed += n;
                demoted.push(cand.cluster);
            }
        }
        (freed, demoted)
    }

    /// Zone selection with explicit budgets (r retrieval, e estimation).
    pub fn select_with(
        &self,
        q: &[f32],
        r: usize,
        e: usize,
        scratch: &mut SelectScratch,
    ) -> ZoneSelection {
        self.select_into(q, r, e, scratch).clone()
    }

    /// `select_with` into the scratch-owned selection (alloc-free after
    /// warmup; the borrow keeps `scratch` usable for trimming in place).
    pub fn select_into<'s>(
        &self,
        q: &[f32],
        r: usize,
        e: usize,
        scratch: &'s mut SelectScratch,
    ) -> &'s mut ZoneSelection {
        let m = self.meta.m();
        if m == 0 || r + e == 0 {
            scratch.sel.retrieval.clear();
            scratch.sel.estimation.clear();
            return &mut scratch.sel;
        }
        // Score all centroids (the GPU's step-1 in Figure 5) in one
        // blocked kernel pass; partial select: top r+e, then top r
        // within them (quickselect via select_nth_unstable — O(m), not
        // O(m log m)).
        let cents = self.meta.centroids_flat();
        scratch.scores.clear();
        scratch.scores.resize(m, 0.0);
        kernels::active().matvec_nt(q, cents, self.d, &mut scratch.scores);
        self.select_from_scores(r, e, scratch);
        &mut scratch.sel
    }

    /// Group-aware zone selection for GQA: `qs` is `[g, d]` flat (the
    /// query heads sharing this KV head); a cluster's score is the MAX
    /// over the group's inner products, so each query head's heavy
    /// hitters are eligible for retrieval (a group-mean query would
    /// systematically miss per-head needles).
    pub fn select_group_with(
        &self,
        qs: &[f32],
        g: usize,
        r: usize,
        e: usize,
        scratch: &mut SelectScratch,
    ) -> ZoneSelection {
        self.select_group_into(qs, g, r, e, scratch).clone()
    }

    /// `select_group_with` into the scratch-owned selection (the decode
    /// assembly hot path — alloc-free after warmup).
    pub fn select_group_into<'s>(
        &self,
        qs: &[f32],
        g: usize,
        r: usize,
        e: usize,
        scratch: &'s mut SelectScratch,
    ) -> &'s mut ZoneSelection {
        let m = self.meta.m();
        let d = self.d;
        debug_assert_eq!(qs.len(), g * d);
        if m == 0 {
            scratch.sel.retrieval.clear();
            scratch.sel.estimation.clear();
            return &mut scratch.sel;
        }
        let cents = self.meta.centroids_flat();
        scratch.scores.clear();
        scratch.scores.resize(m, 0.0);
        if g > 1 {
            // GQA-batched path: one gemm_nt over the whole query group
            // (all g query heads sharing this KV head score every
            // centroid in one blocked pass), then a comparison-only
            // column reduce. Bit-identical to the fused kernel — gemm's
            // row tiling preserves the per-(query, centroid) reduction
            // order, and the reduce replays the same strict-`>` query-
            // order max (property-tested in kernels/mod.rs).
            scratch.gm.clear();
            scratch.gm.resize(g * m, 0.0);
            let bk = kernels::active();
            bk.gemm_nt(qs, cents, d, &mut scratch.gm);
            bk.group_max_reduce(&scratch.gm, g, m, &mut scratch.scores);
        } else {
            kernels::active().group_max_scores(qs, g, cents, d, &mut scratch.scores);
        }
        self.select_from_scores(r, e, scratch);
        &mut scratch.sel
    }

    /// Shared top-(r, e) partial selection over `scratch.scores` into
    /// `scratch.sel`. Ordering is `f32::total_cmp` descending with
    /// cluster id as tie-break: total, so NaN scores (a poisoned query
    /// or centroid) degrade to a deterministic selection instead of the
    /// `partial_cmp().unwrap()` panic this used to hide, and unstable
    /// sorting stays deterministic (and allocation-free, unlike stable
    /// `sort_by`) under ties.
    fn select_from_scores(&self, r: usize, e: usize, scratch: &mut SelectScratch) {
        let m = self.meta.m();
        let r = r.min(m);
        let e = e.min(m - r);
        let SelectScratch { scores, order, sel, .. } = scratch;
        sel.retrieval.clear();
        sel.estimation.clear();
        if r + e == 0 {
            return;
        }
        order.clear();
        order.extend(0..m as u32);
        let scores = &*scores;
        let desc = |a: &u32, b: &u32| {
            scores[*b as usize]
                .total_cmp(&scores[*a as usize])
                .then_with(|| a.cmp(b))
        };
        let cut = (r + e).min(m);
        if cut < m {
            order.select_nth_unstable_by(cut - 1, desc);
        }
        if r > 0 && r < cut {
            order[..cut].select_nth_unstable_by(r - 1, desc);
        }
        sel.retrieval.extend_from_slice(&order[..r]);
        sel.retrieval.sort_unstable_by(desc);
        sel.estimation.extend_from_slice(&order[r..cut]);
    }

    /// Zone selection at the paper's default budgets (1.8% / 23.2%).
    pub fn select(&self, q: &[f32], scratch: &mut SelectScratch) -> ZoneSelection {
        let m = self.meta.m();
        let r = self.cfg.retrieval_clusters(m);
        let e = self.cfg.estimation_clusters(m);
        self.select_with(q, r, e, scratch)
    }

    /// Tripartite attention output for one query, gathering exact tokens
    /// directly from the CPU store (accuracy path; the serving path goes
    /// through the wave buffer instead).
    pub fn attend(&self, q: &[f32], sel: &ZoneSelection, out: &mut [f32]) {
        let mut ds = DecodeScratch::default();
        self.attend_with(q, sel, &mut ds, out)
    }

    /// `attend` reusing caller scratch: gather, index lists, and merge
    /// buffers all come from `ds`, so a steady-state decode step is
    /// allocation-free (asserted in `tests/kernels.rs`).
    pub fn attend_with(
        &self,
        q: &[f32],
        sel: &ZoneSelection,
        ds: &mut DecodeScratch,
        out: &mut [f32],
    ) {
        let d = self.d;
        let DecodeScratch { merge, ex_keys, ex_vals, exact_idx, est_idx } = ds;
        ex_keys.clear();
        ex_vals.clear();
        ex_keys.extend_from_slice(&self.sink_keys);
        ex_vals.extend_from_slice(&self.sink_vals);
        ex_keys.extend_from_slice(&self.pend_keys);
        ex_vals.extend_from_slice(&self.pend_vals);
        for &c in &sel.retrieval {
            for r in &self.cluster_blocks[c as usize] {
                // reads through the spill tier when the block is cold
                self.store.copy_block_kv(*r, ex_keys, ex_vals);
            }
        }
        let n_exact = ex_keys.len() / d;
        exact_idx.clear();
        exact_idx.extend(0..n_exact);
        est_idx.clear();
        est_idx.extend(sel.estimation.iter().map(|&c| c as usize));
        let inp = TripartiteInputs {
            d,
            keys: ex_keys,
            vals: ex_vals,
            exact: exact_idx,
            centroids: self.meta.centroids_flat(),
            vsum: self.meta.vsum_flat(),
            sizes: self.meta.counts(),
            estimated: est_idx,
        };
        tripartite_attention_with(q, &inp, merge, out);
    }

    /// Context positions covered exactly (steady + given retrieval zone).
    pub fn exact_positions(&self, sel: &ZoneSelection) -> Vec<u32> {
        let mut pos = Vec::new();
        pos.extend_from_slice(&self.sink_pos);
        pos.extend_from_slice(&self.pend_pos);
        for &c in &sel.retrieval {
            pos.extend_from_slice(self.meta.cluster_tokens(c as usize));
        }
        pos
    }

    pub fn meta(&self) -> &MetaIndex {
        &self.meta
    }

    pub fn store(&self) -> &HeadStore {
        &self.store
    }

    /// The arena this index's KV blocks are checked out of.
    pub fn arena(&self) -> &Arc<BlockArena> {
        self.store.arena()
    }

    pub fn cfg(&self) -> &ZoneConfig {
        &self.cfg
    }

    pub fn cluster_blocks(&self, c: u32) -> &[BlockRef] {
        &self.cluster_blocks[c as usize]
    }

    /// Tokens currently held in the steady zone (sink + local/pending).
    pub fn steady_tokens(&self) -> usize {
        self.sink_pos.len() + self.pend_pos.len()
    }

    /// Steady-zone KV as flat slices (sink then pending), for the
    /// execution-buffer assembly.
    pub fn steady_kv(&self) -> (Vec<f32>, Vec<f32>) {
        let (sk, sv) = self.sink_kv();
        let (pk, pv) = self.pend_kv();
        let mut k = Vec::with_capacity(sk.len() + pk.len());
        let mut v = Vec::with_capacity(k.capacity());
        k.extend_from_slice(sk);
        k.extend_from_slice(pk);
        v.extend_from_slice(sv);
        v.extend_from_slice(pv);
        (k, v)
    }

    /// Sink-zone KV as borrowed flat slices (zero-copy steady access for
    /// the execution-buffer assembly hot path).
    pub fn sink_kv(&self) -> (&[f32], &[f32]) {
        (&self.sink_keys, &self.sink_vals)
    }

    /// Pending/local-window KV as borrowed flat slices.
    pub fn pend_kv(&self) -> (&[f32], &[f32]) {
        (&self.pend_keys, &self.pend_vals)
    }

    /// Context length seen so far.
    pub fn n_seen(&self) -> usize {
        self.n_seen
    }

    /// Incremental re-clusterings performed (decode-time updates).
    pub fn n_updates(&self) -> usize {
        self.n_updates
    }

    pub fn d(&self) -> usize {
        self.d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full_attention;
    use crate::tensor::dot;
    use crate::util::rng::Rng;
    use crate::util::stats::cosine;

    fn small_cfg() -> ZoneConfig {
        ZoneConfig {
            steady_sink: 4,
            steady_local: 16,
            tokens_per_cluster: 8,
            retrieval_frac: 0.1,
            estimation_frac: 0.3,
            build_segment: 128,
            update_segment: 32,
            kmeans_iters: 8,
            centering: true,
        }
    }

    fn mk_ctx(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (rng.normal_vec(n * d), rng.normal_vec(n * d))
    }

    #[test]
    fn build_partitions_all_tokens() {
        let d = 16;
        let (k, v) = mk_ctx(512, d, 1);
        let idx = WaveIndex::build(small_cfg(), d, 1024, &k, &v, 7);
        // every token is either sink, pending, or in exactly one cluster
        let indexed = idx.meta().n_tokens();
        assert_eq!(indexed + idx.steady_tokens(), 512);
        assert_eq!(idx.n_seen(), 512);
        // positions must form a partition of 0..512
        let mut seen = vec![false; 512];
        for c in 0..idx.meta().m() {
            for &p in idx.meta().cluster_tokens(c) {
                assert!(!seen[p as usize], "token {p} double-indexed");
                seen[p as usize] = true;
            }
        }
        for &p in idx.sink_pos.iter().chain(&idx.pend_pos) {
            assert!(!seen[p as usize]);
            seen[p as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn chunked_build_is_bit_identical_across_chunk_sizes() {
        let d = 16;
        let n = 512;
        let (k, v) = mk_ctx(n, d, 11);
        let arena = BlockArena::shared(d, 1024);
        let mono =
            WaveIndex::try_build_in_for(&arena, DEFAULT_TENANT, small_cfg(), &k, &v, 7).unwrap();
        let want = mono.export_state();
        // chunk sizes straddling every interesting boundary: one token,
        // sub-cluster, cluster size, segment-1 / segment / segment+1
        // (the re-cluster boundary), and the whole prompt at once
        for &cs in &[1usize, 7, 8, 127, 128, 129, 512] {
            let arena = BlockArena::shared(d, 1024);
            let mut idx =
                WaveIndex::begin_build_in_for(&arena, DEFAULT_TENANT, small_cfg(), n, 7);
            let mut scratch = BuildScratch::default();
            let mut fed = 0;
            while fed < n {
                assert!(idx.build_in_progress());
                assert_eq!(idx.build_remaining(), n - fed);
                let c = cs.min(n - fed);
                idx.try_feed_build_with(
                    &k[fed * d..(fed + c) * d],
                    &v[fed * d..(fed + c) * d],
                    &mut scratch,
                )
                .unwrap();
                fed += c;
                if fed < n {
                    // an empty feed mid-build is legal and changes nothing
                    idx.try_feed_build(&[], &[]).unwrap();
                }
            }
            assert!(!idx.build_in_progress(), "chunk size {cs}: build did not close");
            assert_eq!(idx.build_remaining(), 0);
            assert_eq!(idx.export_state(), want, "chunk size {cs}: state diverged");
        }
    }

    #[test]
    fn chunked_build_random_partitions_property() {
        // property sweep: random chunk partitions over varying context
        // lengths all converge to the monolithic build's exact bytes
        let d = 8;
        for trial in 0..20u64 {
            let n = 64 + (trial as usize * 37) % 448;
            let (k, v) = mk_ctx(n, d, 100 + trial);
            let arena = BlockArena::shared(d, 512);
            let mono =
                WaveIndex::try_build_in_for(&arena, DEFAULT_TENANT, small_cfg(), &k, &v, trial)
                    .unwrap();
            let want = mono.export_state();
            let mut rng = Rng::new(1000 + trial);
            let arena = BlockArena::shared(d, 512);
            let mut idx =
                WaveIndex::begin_build_in_for(&arena, DEFAULT_TENANT, small_cfg(), n, trial);
            let mut fed = 0;
            while fed < n {
                let c = (1 + rng.below(95)).min(n - fed);
                idx.try_feed_build(&k[fed * d..(fed + c) * d], &v[fed * d..(fed + c) * d])
                    .unwrap();
                fed += c;
            }
            assert!(!idx.build_in_progress(), "trial {trial}");
            assert_eq!(idx.export_state(), want, "trial {trial} (n={n}) diverged");
        }
    }

    #[test]
    fn chunked_build_then_append_matches_monolithic_then_append() {
        // the decode-time append/re-cluster path must behave identically
        // on top of a chunked build and a monolithic one
        let d = 16;
        let n = 512;
        let extra = 64;
        let (k, v) = mk_ctx(n + extra, d, 13);
        let arena = BlockArena::shared(d, 1024);
        let mut mono = WaveIndex::try_build_in_for(
            &arena,
            DEFAULT_TENANT,
            small_cfg(),
            &k[..n * d],
            &v[..n * d],
            5,
        )
        .unwrap();
        let arena2 = BlockArena::shared(d, 1024);
        let mut chunked =
            WaveIndex::begin_build_in_for(&arena2, DEFAULT_TENANT, small_cfg(), n, 5);
        let mut fed = 0;
        while fed < n {
            let c = 100.min(n - fed);
            chunked
                .try_feed_build(&k[fed * d..(fed + c) * d], &v[fed * d..(fed + c) * d])
                .unwrap();
            fed += c;
        }
        for i in n..n + extra {
            mono.append(&k[i * d..(i + 1) * d], &v[i * d..(i + 1) * d]);
            chunked.append(&k[i * d..(i + 1) * d], &v[i * d..(i + 1) * d]);
        }
        assert!(mono.n_updates() > 0, "appends must trigger re-clustering");
        assert_eq!(mono.export_state(), chunked.export_state());
    }

    #[test]
    fn chunked_grafted_build_matches_monolithic_graft() {
        let d = 16;
        let n = 512;
        let (k, v) = mk_ctx(n, d, 17);
        // donor seals a prefix; both grafted builds attach the same slot
        let arena = BlockArena::shared(d, 1024);
        let mut donor =
            WaveIndex::try_build_in_for(&arena, DEFAULT_TENANT, small_cfg(), &k, &v, 9).unwrap();
        let sealed = donor.seal_prefix(300);
        assert!(!sealed.clusters.is_empty());
        // graft coverage = exactly the tokens the sealed clusters hold
        // (the registry guarantees this alignment in the engine path)
        let covered = sealed
            .clusters
            .iter()
            .flat_map(|c| c.pos.iter())
            .map(|&p| p as usize + 1)
            .max()
            .unwrap();
        let mono = WaveIndex::try_build_grafted_in_for(
            &arena,
            DEFAULT_TENANT,
            small_cfg(),
            &sealed,
            covered,
            &k,
            &v,
            9,
        )
        .unwrap();
        let want = mono.export_state();
        for &cs in &[33usize, 128, 256, 512] {
            let mut idx = WaveIndex::begin_build_grafted_in_for(
                &arena,
                DEFAULT_TENANT,
                small_cfg(),
                &sealed,
                covered,
                n,
                9,
            );
            let mut fed = 0;
            while fed < n {
                let c = cs.min(n - fed);
                idx.try_feed_build(&k[fed * d..(fed + c) * d], &v[fed * d..(fed + c) * d])
                    .unwrap();
                fed += c;
            }
            assert!(!idx.build_in_progress());
            assert_eq!(idx.export_state(), want, "graft chunk size {cs} diverged");
        }
    }

    #[test]
    fn mid_build_snapshot_is_refused() {
        let d = 8;
        let (k, v) = mk_ctx(64, d, 3);
        let arena = BlockArena::shared(d, 512);
        let mut idx = WaveIndex::begin_build_in_for(&arena, DEFAULT_TENANT, small_cfg(), 128, 1);
        idx.try_feed_build(&k, &v).unwrap();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| idx.export_state()));
        assert!(r.is_err(), "mid-build export must be refused");
    }

    #[test]
    fn lossy_clearance_respects_zone_rules_and_floor() {
        let d = 16;
        let (k, v) = mk_ctx(512, d, 1);
        let mut idx = WaveIndex::build(small_cfg(), d, 1024, &k, &v, 7);
        let m = idx.meta().m();
        assert!(m > 0);
        // permissive floor: interior clusters clear (build keeps every
        // cluster outside the steady zones, so the zone rules pass)
        idx.set_lossy_cos_floor(0.0);
        assert!((0..m).any(|c| idx.cluster_lossy_ok(c as u32)));
        // positional rule, trailing window: widening `steady_local`
        // until it swallows the clustered span pulls every cluster back
        // to exact storage regardless of the floor
        idx.cfg.steady_local = idx.n_seen;
        assert!((0..m).all(|c| !idx.cluster_lossy_ok(c as u32)));
        idx.cfg.steady_local = small_cfg().steady_local;
        // positional rule, sink: same with the sink boundary
        idx.cfg.steady_sink = idx.n_seen;
        assert!((0..m).all(|c| !idx.cluster_lossy_ok(c as u32)));
        idx.cfg.steady_sink = small_cfg().steady_sink;
        // an unreachable floor forbids lossy outright again
        idx.set_lossy_cos_floor(1.0);
        assert!((0..m).all(|c| !idx.cluster_lossy_ok(c as u32)));
    }

    #[test]
    fn full_budget_matches_full_attention() {
        let d = 16;
        let (k, v) = mk_ctx(256, d, 2);
        let idx = WaveIndex::build(small_cfg(), d, 1024, &k, &v, 3);
        let mut rng = Rng::new(9);
        let q = rng.normal_vec(d);
        let m = idx.meta().m();
        let mut scratch = SelectScratch::default();
        let sel = idx.select_with(&q, m, 0, &mut scratch); // retrieve ALL clusters
        let mut out = vec![0.0; d];
        idx.attend(&q, &sel, &mut out);
        let mut full = vec![0.0; d];
        full_attention(&q, &k, &v, d, &mut full);
        assert!(
            cosine(&out, &full) > 0.999,
            "full retrieval must equal full attention: {}",
            cosine(&out, &full)
        );
    }

    #[test]
    fn sparse_budget_close_to_full_attention() {
        // Clustered geometry: sparse retrieval + estimation tracks full.
        let d = 16;
        let n = 512;
        let mut rng = Rng::new(4);
        // keys in 8 bundles
        let dirs: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(d)).collect();
        let mut k = Vec::new();
        for i in 0..n {
            let dir = &dirs[(i / 16) % 8];
            for j in 0..d {
                k.push(dir[j] * 2.0 + 0.3 * rng.normal_f32());
            }
        }
        let v = rng.normal_vec(n * d);
        let idx = WaveIndex::build(small_cfg(), d, 1024, &k, &v, 5);
        let q: Vec<f32> = dirs[3].iter().map(|x| x * 1.5).collect();
        let mut scratch = SelectScratch::default();
        let sel = idx.select(&q, &mut scratch);
        assert!(!sel.retrieval.is_empty());
        let mut out = vec![0.0; d];
        idx.attend(&q, &sel, &mut out);
        let mut full = vec![0.0; d];
        full_attention(&q, &k, &v, d, &mut full);
        assert!(
            cosine(&out, &full) > 0.95,
            "sparse wave attention cos = {}",
            cosine(&out, &full)
        );
    }

    #[test]
    fn selection_budgets_respected() {
        let d = 8;
        let (k, v) = mk_ctx(400, d, 6);
        let idx = WaveIndex::build(small_cfg(), d, 512, &k, &v, 8);
        let q = vec![0.5; d];
        let mut scratch = SelectScratch::default();
        let sel = idx.select_with(&q, 3, 5, &mut scratch);
        assert_eq!(sel.retrieval.len(), 3);
        assert_eq!(sel.estimation.len(), 5);
        // disjoint
        for c in &sel.retrieval {
            assert!(!sel.estimation.contains(c));
        }
        // retrieval scores >= estimation scores
        let score = |c: u32| dot(&q, idx.meta().centroid(c as usize));
        let min_r = sel.retrieval.iter().map(|&c| score(c)).fold(f32::INFINITY, f32::min);
        let max_e = sel.estimation.iter().map(|&c| score(c)).fold(f32::NEG_INFINITY, f32::max);
        assert!(min_r >= max_e - 1e-5, "zones out of order: {min_r} < {max_e}");
    }

    #[test]
    fn nan_scores_select_without_panicking() {
        // regression: a NaN query used to panic selection through
        // partial_cmp().unwrap(); total_cmp must keep budgets and
        // determinism instead.
        let d = 8;
        let (k, v) = mk_ctx(400, d, 6);
        let idx = WaveIndex::build(small_cfg(), d, 512, &k, &v, 8);
        let q = vec![f32::NAN; d];
        let mut scratch = SelectScratch::default();
        let sel = idx.select_with(&q, 3, 5, &mut scratch);
        assert_eq!(sel.retrieval.len(), 3);
        assert_eq!(sel.estimation.len(), 5);
        let again = idx.select_with(&q, 3, 5, &mut scratch);
        assert_eq!(sel, again, "NaN selection must be deterministic");
        // a single poisoned lane (NaN scores only where q hits it) also
        // survives the group path
        let mut qs = vec![0.5; 2 * d];
        qs[0] = f32::NAN;
        let gsel = idx.select_group_with(&qs, 2, 3, 5, &mut scratch);
        assert_eq!(gsel.retrieval.len(), 3);
        assert_eq!(gsel.estimation.len(), 5);
    }

    #[test]
    fn retrieval_ordered_best_first() {
        let d = 8;
        let (k, v) = mk_ctx(400, d, 10);
        let idx = WaveIndex::build(small_cfg(), d, 512, &k, &v, 11);
        let q = vec![0.3; d];
        let mut scratch = SelectScratch::default();
        let sel = idx.select_with(&q, 6, 0, &mut scratch);
        let scores: Vec<f32> =
            sel.retrieval.iter().map(|&c| dot(&q, idx.meta().centroid(c as usize))).collect();
        for w in scores.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
    }

    #[test]
    fn append_triggers_incremental_update() {
        let d = 8;
        let cfg = small_cfg();
        let (k, v) = mk_ctx(64, d, 12);
        let mut idx = WaveIndex::build(cfg.clone(), d, 512, &k, &v, 13);
        let m0 = idx.meta().m();
        let mut rng = Rng::new(14);
        // push enough tokens to trip a re-clustering
        for _ in 0..(cfg.steady_local + cfg.update_segment + 4) {
            let key = rng.normal_vec(d);
            let val = rng.normal_vec(d);
            idx.append(&key, &val);
        }
        assert!(idx.n_updates() >= 1);
        assert!(idx.meta().m() > m0);
        // steady zone stays bounded
        assert!(idx.steady_tokens() <= cfg.steady_sink + cfg.steady_local + cfg.update_segment);
        // no token lost
        assert_eq!(idx.meta().n_tokens() + idx.steady_tokens(), idx.n_seen());
    }

    #[test]
    fn try_build_failure_leaves_arena_unchanged() {
        let d = 16;
        let (k, v) = mk_ctx(512, d, 30);
        let arena = BlockArena::shared(d, 512); // tpb = 4
        arena.set_capacity_blocks(Some(10));
        let err = WaveIndex::try_build_in_for(&arena, 3, small_cfg(), &k, &v, 1).unwrap_err();
        assert!(matches!(err, AllocError::ArenaFull { .. }));
        assert_eq!(arena.live_blocks(), 0, "failed build must return every block");
        assert_eq!(arena.tenant_live_blocks(3), 0);
        // lifting the cap lets the same build succeed, and finishing the
        // session returns the pool to empty
        arena.set_capacity_blocks(None);
        let idx = WaveIndex::try_build_in_for(&arena, 3, small_cfg(), &k, &v, 1).unwrap();
        assert!(arena.live_blocks() > 0);
        drop(idx);
        assert_eq!(arena.live_blocks(), 0);
    }

    #[test]
    fn try_append_failure_restores_pending_tokens() {
        let d = 8;
        let cfg = small_cfg(); // sink 4, local 16, update_segment 32
        let arena = BlockArena::shared(d, 512); // tpb = 8
        let (k, v) = mk_ctx(64, d, 31);
        let mut idx =
            WaveIndex::try_build_in_for(&arena, 0, cfg.clone(), &k, &v, 13).unwrap();
        // freeze the arena at current occupancy: re-clustering must fail
        arena.set_capacity_blocks(Some(arena.live_blocks()));
        let mut rng = Rng::new(14);
        let mut failed = 0;
        for _ in 0..(cfg.steady_local + cfg.update_segment + 8) {
            let key = rng.normal_vec(d);
            let val = rng.normal_vec(d);
            if idx.try_append(&key, &val).is_err() {
                failed += 1;
            }
        }
        assert!(failed > 0, "capped arena must refuse the re-clustering");
        // no token lost: every token is still in exactly one of
        // {sink, pending, some cluster}
        assert_eq!(idx.meta().n_tokens() + idx.steady_tokens(), idx.n_seen());
        // lifting the cap lets the deferred re-clustering land on a later
        // append (the pending buffer is still over threshold)
        arena.set_capacity_blocks(None);
        let n_upd = idx.n_updates();
        let key = rng.normal_vec(d);
        let val = rng.normal_vec(d);
        idx.try_append(&key, &val).unwrap();
        assert!(idx.n_updates() > n_upd, "re-clustering must resume after reclamation");
        assert_eq!(idx.meta().n_tokens() + idx.steady_tokens(), idx.n_seen());
    }

    #[test]
    fn short_context_all_steady() {
        let d = 8;
        let (k, v) = mk_ctx(10, d, 15);
        let idx = WaveIndex::build(small_cfg(), d, 512, &k, &v, 16);
        assert_eq!(idx.meta().m(), 0);
        assert_eq!(idx.steady_tokens(), 10);
        // select on an empty index is a no-op
        let q = vec![1.0; d];
        let mut scratch = SelectScratch::default();
        let sel = idx.select(&q, &mut scratch);
        assert!(sel.is_empty());
        // attend still works (pure steady attention)
        let mut out = vec![0.0; d];
        idx.attend(&q, &sel, &mut out);
        let mut full = vec![0.0; d];
        full_attention(&q, &k, &v, d, &mut full);
        assert!(cosine(&out, &full) > 0.999);
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn cluster_kv(idx: &WaveIndex, c: usize) -> (Vec<f32>, Vec<f32>) {
        let (mut k, mut v) = (Vec::new(), Vec::new());
        for r in idx.cluster_blocks(c as u32) {
            idx.store().copy_block_kv(*r, &mut k, &mut v);
        }
        (k, v)
    }

    #[test]
    fn export_import_roundtrips_bit_exact() {
        let d = 16;
        let (k, v) = mk_ctx(512, d, 44);
        let arena = BlockArena::shared(d, 1024); // tpb = 8
        let mut idx = WaveIndex::try_build_in_for(&arena, 1, small_cfg(), &k, &v, 77).unwrap();
        // decode-time appends so pend and n_updates are non-trivial
        let mut rng = Rng::new(45);
        for _ in 0..56 {
            let key = rng.normal_vec(d);
            let val = rng.normal_vec(d);
            idx.append(&key, &val);
        }
        assert!(idx.n_updates() >= 1);
        // demote one cluster so export must read through the spill tier
        assert!(idx.demote_cluster(0) > 0);
        let snap = idx.export_state();
        // DIFFERENT block stride on the target: pages re-pack
        let arena2 = BlockArena::shared(d, 512); // tpb = 4
        let got = WaveIndex::import_state(&arena2, 2, small_cfg(), &snap).unwrap();
        assert_eq!(got.meta().m(), idx.meta().m());
        assert_eq!(got.n_seen(), idx.n_seen());
        assert_eq!(got.n_updates(), idx.n_updates());
        assert_eq!(got.steady_tokens(), idx.steady_tokens());
        for c in 0..idx.meta().m() {
            assert_eq!(got.meta().cluster_tokens(c), idx.meta().cluster_tokens(c));
            assert_eq!(bits(got.meta().centroid(c)), bits(idx.meta().centroid(c)));
            let (k1, v1) = cluster_kv(&idx, c);
            let (k2, v2) = cluster_kv(&got, c);
            assert_eq!(bits(&k2), bits(&k1), "cluster {c} keys drifted");
            assert_eq!(bits(&v2), bits(&v1), "cluster {c} vals drifted");
        }
        let (sk1, sv1) = idx.steady_kv();
        let (sk2, sv2) = got.steady_kv();
        assert_eq!(bits(&sk2), bits(&sk1));
        assert_eq!(bits(&sv2), bits(&sv1));
        // same query ⇒ same selection, bit-identical attention output
        let q = Rng::new(46).normal_vec(d);
        let (mut s1, mut s2) = (SelectScratch::default(), SelectScratch::default());
        let sel1 = idx.select(&q, &mut s1);
        let sel2 = got.select(&q, &mut s2);
        assert_eq!(sel1, sel2);
        let (mut o1, mut o2) = (vec![0.0; d], vec![0.0; d]);
        idx.attend(&q, &sel1, &mut o1);
        got.attend(&q, &sel2, &mut o2);
        assert_eq!(bits(&o1), bits(&o2), "attention must be bit-identical after import");
        // the clustering seed survives: identical future appends
        // re-cluster identically on both sides
        let (mut a, mut b) = (idx, got);
        let mut rng = Rng::new(47);
        for _ in 0..64 {
            let key = rng.normal_vec(d);
            let val = rng.normal_vec(d);
            a.append(&key, &val);
            b.append(&key, &val);
        }
        assert_eq!(a.meta().m(), b.meta().m());
        let last = a.meta().m() - 1;
        assert_eq!(bits(b.meta().centroid(last)), bits(a.meta().centroid(last)));
        assert_eq!(b.meta().cluster_tokens(last), a.meta().cluster_tokens(last));
    }

    #[test]
    fn import_rejects_corrupt_and_mismatched_snapshots() {
        let d = 16;
        let (k, v) = mk_ctx(256, d, 50);
        let idx = WaveIndex::build(small_cfg(), d, 1024, &k, &v, 9);
        let snap = idx.export_state();
        let ok_arena = BlockArena::shared(d, 512);
        // head-dimension mismatch
        let bad_d = BlockArena::shared(8, 512);
        assert!(matches!(
            WaveIndex::import_state(&bad_d, 0, small_cfg(), &snap),
            Err(SnapshotError::Geometry { field: "d", .. })
        ));
        // truncation anywhere fails soft
        assert!(matches!(
            WaveIndex::import_state(&ok_arena, 0, small_cfg(), &snap[..snap.len() - 1]),
            Err(SnapshotError::Corrupt(_))
        ));
        assert!(matches!(
            WaveIndex::import_state(&ok_arena, 0, small_cfg(), &snap[..10]),
            Err(SnapshotError::Corrupt(_))
        ));
        // trailing garbage is rejected, not ignored
        let mut long = snap.clone();
        long.push(0);
        assert!(matches!(
            WaveIndex::import_state(&ok_arena, 0, small_cfg(), &long),
            Err(SnapshotError::Corrupt("trailing bytes"))
        ));
        // bad magic
        let mut bad_magic = snap.clone();
        bad_magic[0] ^= 0xff;
        assert!(matches!(
            WaveIndex::import_state(&ok_arena, 0, small_cfg(), &bad_magic),
            Err(SnapshotError::Corrupt("bad magic"))
        ));
        // a capped target arena refuses mid-rebuild and leaves no residue
        let capped = BlockArena::shared(d, 512);
        capped.set_capacity_blocks(Some(2));
        assert!(matches!(
            WaveIndex::import_state(&capped, 3, small_cfg(), &snap),
            Err(SnapshotError::Alloc(_))
        ));
        assert_eq!(capped.live_blocks(), 0, "failed import must return every block");
        assert_eq!(capped.tenant_live_blocks(3), 0);
        // the pristine snapshot still imports fine afterwards
        assert!(WaveIndex::import_state(&ok_arena, 0, small_cfg(), &snap).is_ok());
    }

    #[test]
    fn exact_positions_cover_selection() {
        let d = 8;
        let (k, v) = mk_ctx(300, d, 17);
        let idx = WaveIndex::build(small_cfg(), d, 512, &k, &v, 18);
        let q = vec![0.2; d];
        let mut scratch = SelectScratch::default();
        let sel = idx.select_with(&q, 2, 2, &mut scratch);
        let pos = idx.exact_positions(&sel);
        let n_cluster_tokens: usize =
            sel.retrieval.iter().map(|&c| idx.meta().cluster_tokens(c as usize).len()).sum();
        assert_eq!(pos.len(), idx.steady_tokens() + n_cluster_tokens);
    }
}
