//! Meta index: the GPU-resident representatives of all clusters
//! (paper Figure 5) — centroids, summed value vectors, cluster sizes —
//! stored flat SoA for the scoring hot path.

/// Per-head meta index. Cluster ids are stable: appended by segmented
/// build/update, never reordered.
pub struct MetaIndex {
    d: usize,
    /// `[m, d]` centroid means (original space).
    centroids: Vec<f32>,
    /// `[m, d]` summed value vectors (Eq. 4's VS).
    vsum: Vec<f32>,
    /// `[m]` cluster sizes.
    counts: Vec<f32>,
    /// Token context positions per cluster (analysis + exact attention).
    tokens: Vec<Vec<u32>>,
}

impl MetaIndex {
    pub fn new(d: usize) -> Self {
        MetaIndex { d, centroids: Vec::new(), vsum: Vec::new(), counts: Vec::new(), tokens: Vec::new() }
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of clusters.
    pub fn m(&self) -> usize {
        self.counts.len()
    }

    /// Total indexed tokens.
    pub fn n_tokens(&self) -> usize {
        self.counts.iter().map(|&c| c as usize).sum()
    }

    /// Append one cluster; returns its id.
    pub fn push(&mut self, centroid: &[f32], vsum: &[f32], tokens: Vec<u32>) -> usize {
        debug_assert_eq!(centroid.len(), self.d);
        debug_assert_eq!(vsum.len(), self.d);
        debug_assert!(!tokens.is_empty());
        self.centroids.extend_from_slice(centroid);
        self.vsum.extend_from_slice(vsum);
        self.counts.push(tokens.len() as f32);
        self.tokens.push(tokens);
        self.counts.len() - 1
    }

    pub fn centroid(&self, c: usize) -> &[f32] {
        &self.centroids[c * self.d..(c + 1) * self.d]
    }

    pub fn centroids_flat(&self) -> &[f32] {
        &self.centroids
    }

    pub fn vsum_flat(&self) -> &[f32] {
        &self.vsum
    }

    pub fn counts(&self) -> &[f32] {
        &self.counts
    }

    pub fn cluster_tokens(&self, c: usize) -> &[u32] {
        &self.tokens[c]
    }

    /// GPU bytes consumed by the meta index (centroids + vsum + counts),
    /// f32 elements — the paper's "small memory footprint" claim.
    pub fn gpu_bytes(&self) -> usize {
        (self.centroids.len() + self.vsum.len() + self.counts.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_lookup() {
        let mut mi = MetaIndex::new(4);
        let id0 = mi.push(&[1.0, 0.0, 0.0, 0.0], &[2.0; 4], vec![0, 5, 9]);
        let id1 = mi.push(&[0.0, 1.0, 0.0, 0.0], &[3.0; 4], vec![2]);
        assert_eq!((id0, id1), (0, 1));
        assert_eq!(mi.m(), 2);
        assert_eq!(mi.n_tokens(), 4);
        assert_eq!(mi.centroid(1), &[0.0, 1.0, 0.0, 0.0]);
        assert_eq!(mi.counts(), &[3.0, 1.0]);
        assert_eq!(mi.cluster_tokens(0), &[0, 5, 9]);
    }

    #[test]
    fn gpu_bytes_scales_with_m() {
        let mut mi = MetaIndex::new(8);
        mi.push(&[0.0; 8], &[0.0; 8], vec![1]);
        // (8 + 8 + 1) f32 = 68 bytes
        assert_eq!(mi.gpu_bytes(), 68);
    }
}
