//! Spherical k-means with the all-but-the-top centering technique
//! (paper §4.2, inspired by MagicPIG): clustering is performed on
//! mean-centered keys so that the dominant shared component of key vectors
//! does not mask the attention-relevant directions; centroids are reported
//! in the *original* space (the Jensen bound of Eq. 3 needs true means).

use crate::kernels;
use crate::tensor::{axpy, norm, scale};
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

/// Result of clustering a segment of keys.
#[derive(Clone, Debug)]
pub struct Clustering {
    /// Number of clusters (some may be empty and are dropped by callers).
    pub k: usize,
    /// `[k, d]` centroid means in the original (uncentered) space.
    pub centroids: Vec<f32>,
    /// Cluster assignment per input key.
    pub assign: Vec<u32>,
    /// Member count per cluster.
    pub counts: Vec<u32>,
}

/// Spherical k-means over `[n, d]` keys.
///
/// * assignment metric: cosine on centered keys (normalized directions);
/// * update: centroid = mean of members (direction renormalized);
/// * init: evenly strided over the sequence — positional striding is the
///   natural seed under RoPE spatial locality and is deterministic;
/// * early exit when assignments stabilize.
pub fn spherical_kmeans(
    keys: &[f32],
    d: usize,
    k: usize,
    iters: usize,
    centering: bool,
    seed: u64,
) -> Clustering {
    spherical_kmeans_pooled(keys, d, k, iters, centering, seed, None)
}

/// [`spherical_kmeans`] with the assignment pass fanned out over key
/// chunks on a [`ThreadPool`]. Bit-identical to the serial path for any
/// thread count: chunking only partitions the GEMM's A rows, and the
/// kernel layer's `gemm_nt` is partition-invariant (each score is one
/// fixed-order row dot), so per-key argmax and the summed `changed`
/// count cannot differ (property-tested in this module).
///
/// Callers already running ON pool workers (e.g. the decode append
/// fan-out reaching `try_cluster_segment`) must pass `None`: scoping a
/// nested fan-out from a worker thread deadlocks the pool.
pub fn spherical_kmeans_pooled(
    keys: &[f32],
    d: usize,
    k: usize,
    iters: usize,
    centering: bool,
    seed: u64,
    pool: Option<&ThreadPool>,
) -> Clustering {
    let n = keys.len() / d;
    assert!(n > 0 && k > 0);
    let k = k.min(n);

    // Center: x' = x - mu (all-but-the-top, first component only).
    let mut mu = vec![0.0f32; d];
    if centering {
        for i in 0..n {
            axpy(1.0, &keys[i * d..(i + 1) * d], &mut mu);
        }
        scale(&mut mu, 1.0 / n as f32);
    }
    let mut centered = vec![0.0f32; n * d];
    for i in 0..n {
        for j in 0..d {
            centered[i * d + j] = keys[i * d + j] - mu[j];
        }
    }

    // Init: strided positions, jittered deterministically.
    let mut rng = Rng::new(seed ^ 0x9e3779b97f4a7c15);
    let mut dirs = vec![0.0f32; k * d];
    for c in 0..k {
        let base = c * n / k;
        let pick = base + rng.below((n / k).max(1));
        let row = &centered[pick.min(n - 1) * d..pick.min(n - 1) * d + d];
        dirs[c * d..(c + 1) * d].copy_from_slice(row);
        normalize(&mut dirs[c * d..(c + 1) * d]);
    }

    let mut assign = vec![0u32; n];
    let mut counts = vec![0u32; k];
    let mut tile = Vec::new();
    for it in 0..iters.max(1) {
        // Assign to nearest direction by cosine: score key tiles against
        // ALL directions with the kernel layer's blocked GEMM (AVX2 when
        // detected), then per-key argmax with strict `>` first-wins
        // tie-break. The pooled variant partitions keys across workers;
        // gemm_nt is partition-invariant so results are bit-identical.
        let ctx = AssignCtx { centered: &centered, dirs: &dirs, d, k, force: it == 0 };
        let changed = match pool {
            Some(pool) if n >= 2 * ASSIGN_TILE_KEYS && pool.n_threads() > 1 => {
                let chunk = n.div_ceil(pool.n_threads()).max(ASSIGN_TILE_KEYS);
                let mut parts: Vec<(usize, &mut [u32], usize)> = assign
                    .chunks_mut(chunk)
                    .enumerate()
                    .map(|(ci, ch)| (ci * chunk, ch, 0usize))
                    .collect();
                let run = |_t: usize, part: &mut (usize, &mut [u32], usize)| {
                    let mut tile = Vec::new();
                    part.2 = assign_chunk(&ctx, part.0, part.1, &mut tile);
                };
                pool.scope_for_each_mut(&mut parts, &run);
                parts.iter().map(|p| p.2).sum()
            }
            _ => assign_chunk(&ctx, 0, &mut assign, &mut tile),
        };
        // Update directions = normalized mean of members (centered space).
        dirs.iter_mut().for_each(|x| *x = 0.0);
        counts.iter_mut().for_each(|c| *c = 0);
        for i in 0..n {
            let c = assign[i] as usize;
            counts[c] += 1;
            axpy(1.0, &centered[i * d..(i + 1) * d], &mut dirs[c * d..(c + 1) * d]);
        }
        for c in 0..k {
            if counts[c] > 0 {
                normalize(&mut dirs[c * d..(c + 1) * d]);
            } else {
                // Re-seed empty cluster at the farthest-assigned point.
                let far = rng.below(n);
                dirs[c * d..(c + 1) * d].copy_from_slice(&centered[far * d..(far + 1) * d]);
                normalize(&mut dirs[c * d..(c + 1) * d]);
            }
        }
        // Converged-enough exit: <0.5% of points moving no longer shifts
        // centroid means measurably (the paper uses a fixed 10 iterations;
        // this is a strict refinement that preserves the Eq. 3 bound —
        // final centroids are recomputed as exact means below).
        if changed * 200 < n {
            break;
        }
    }

    // Final centroids: true means in the ORIGINAL space (Eq. 3 bound).
    let mut centroids = vec![0.0f32; k * d];
    counts.iter_mut().for_each(|c| *c = 0);
    for i in 0..n {
        let c = assign[i] as usize;
        counts[c] += 1;
        axpy(1.0, &keys[i * d..(i + 1) * d], &mut centroids[c * d..(c + 1) * d]);
    }
    for c in 0..k {
        if counts[c] > 0 {
            scale(&mut centroids[c * d..(c + 1) * d], 1.0 / counts[c] as f32);
        }
    }

    Clustering { k, centroids, assign, counts }
}

/// Keys per GEMM tile in the assignment pass: 32 rows of scores against
/// every direction (32·k f32) stays L1-resident at segment-scale k.
const ASSIGN_TILE_KEYS: usize = 32;

/// Shared read-only inputs of one assignment pass.
struct AssignCtx<'a> {
    centered: &'a [f32],
    dirs: &'a [f32],
    d: usize,
    k: usize,
    /// First iteration: count every key as changed (forces at least one
    /// update pass even if the strided init already agrees).
    force: bool,
}

/// Assign the keys `base..base + assign.len()` (rows of `ctx.centered`)
/// to their best direction; returns how many assignments changed.
/// `tile` is reusable `[tile_keys, k]` score scratch.
fn assign_chunk(
    ctx: &AssignCtx<'_>,
    base: usize,
    assign: &mut [u32],
    tile: &mut Vec<f32>,
) -> usize {
    let (d, k) = (ctx.d, ctx.k);
    let bk = kernels::active();
    let mut changed = 0usize;
    let mut i0 = 0;
    while i0 < assign.len() {
        let tn = (assign.len() - i0).min(ASSIGN_TILE_KEYS);
        tile.clear();
        tile.resize(tn * k, 0.0);
        let a = &ctx.centered[(base + i0) * d..(base + i0 + tn) * d];
        bk.gemm_nt(a, ctx.dirs, d, tile);
        for ii in 0..tn {
            let row = &tile[ii * k..(ii + 1) * k];
            let mut best = 0u32;
            let mut best_s = f32::NEG_INFINITY;
            for (c, &s) in row.iter().enumerate() {
                if s > best_s {
                    best_s = s;
                    best = c as u32;
                }
            }
            let slot = &mut assign[i0 + ii];
            if *slot != best || ctx.force {
                changed += 1;
                *slot = best;
            }
        }
        i0 += tn;
    }
    changed
}

fn normalize(x: &mut [f32]) {
    let nrm = norm(x);
    if nrm > 1e-12 {
        scale(x, 1.0 / nrm);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Two well-separated gaussian bundles must be split cleanly.
    #[test]
    fn separates_two_bundles() {
        let d = 16;
        let mut rng = Rng::new(42);
        let mut keys = Vec::new();
        let dir_a: Vec<f32> = (0..d).map(|i| if i == 0 { 10.0 } else { 0.0 }).collect();
        let dir_b: Vec<f32> = (0..d).map(|i| if i == 1 { 10.0 } else { 0.0 }).collect();
        for i in 0..64 {
            let base = if i % 2 == 0 { &dir_a } else { &dir_b };
            for j in 0..d {
                keys.push(base[j] + 0.1 * rng.normal_f32());
            }
        }
        let c = spherical_kmeans(&keys, d, 2, 10, false, 1);
        // all even-index keys together, all odd together
        let a0 = c.assign[0];
        for i in 0..64 {
            if i % 2 == 0 {
                assert_eq!(c.assign[i], a0, "even key {i}");
            } else {
                assert_ne!(c.assign[i], a0, "odd key {i}");
            }
        }
        assert_eq!(c.counts.iter().sum::<u32>(), 64);
    }

    /// Centroid of a cluster must equal the mean of its members
    /// (the Jensen bound of Eq. 3 depends on this exactly).
    #[test]
    fn centroids_are_member_means() {
        let d = 8;
        let mut rng = Rng::new(7);
        let keys = rng.normal_vec(40 * d);
        let c = spherical_kmeans(&keys, d, 4, 10, true, 2);
        for ci in 0..c.k {
            if c.counts[ci] == 0 {
                continue;
            }
            let mut mean = vec![0.0f32; d];
            for i in 0..40 {
                if c.assign[i] as usize == ci {
                    axpy(1.0, &keys[i * d..(i + 1) * d], &mut mean);
                }
            }
            scale(&mut mean, 1.0 / c.counts[ci] as f32);
            for j in 0..d {
                assert!((mean[j] - c.centroids[ci * d + j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn k_clamped_to_n() {
        let d = 4;
        let keys = vec![1.0f32; 3 * d];
        let c = spherical_kmeans(&keys, d, 16, 5, false, 3);
        assert_eq!(c.k, 3);
        assert_eq!(c.assign.len(), 3);
    }

    #[test]
    fn deterministic_given_seed() {
        let d = 8;
        let mut rng = Rng::new(9);
        let keys = rng.normal_vec(100 * d);
        let a = spherical_kmeans(&keys, d, 8, 10, true, 5);
        let b = spherical_kmeans(&keys, d, 8, 10, true, 5);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.centroids, b.centroids);
    }

    #[test]
    fn all_tokens_assigned() {
        let d = 8;
        let mut rng = Rng::new(13);
        let keys = rng.normal_vec(333 * d);
        let c = spherical_kmeans(&keys, d, 21, 10, true, 6);
        assert_eq!(c.counts.iter().sum::<u32>() as usize, 333);
        assert!(c.assign.iter().all(|&a| (a as usize) < c.k));
    }

    /// Pooled assignment must be bit-identical to serial for any worker
    /// count: chunking only partitions the GEMM's A rows, which the
    /// kernel layer guarantees is reduction-order invariant.
    #[test]
    fn pooled_matches_serial_bit_identical() {
        let d = 12;
        let mut rng = Rng::new(31);
        for &(n, k) in &[(97usize, 7usize), (256, 16), (500, 23)] {
            let keys = rng.normal_vec(n * d);
            let serial = spherical_kmeans(&keys, d, k, 10, true, 17);
            for threads in [2, 3, 5] {
                let pool = ThreadPool::new(threads);
                let pooled =
                    spherical_kmeans_pooled(&keys, d, k, 10, true, 17, Some(&pool));
                assert_eq!(serial.assign, pooled.assign, "n={n} k={k} threads={threads}");
                assert_eq!(serial.centroids, pooled.centroids);
                assert_eq!(serial.counts, pooled.counts);
            }
        }
    }

    /// Centering must help when keys share a large common component —
    /// the MagicPIG observation the paper adopts.
    #[test]
    fn centering_recovers_structure_under_shared_offset() {
        let d = 16;
        let mut rng = Rng::new(21);
        let mut keys = Vec::new();
        // Huge shared offset in dim 0; true structure in dims 1/2.
        for i in 0..64 {
            for j in 0..d {
                let structural = if i % 2 == 0 && j == 1 {
                    3.0
                } else if i % 2 == 1 && j == 2 {
                    3.0
                } else {
                    0.0
                };
                let shared = if j == 0 { 50.0 } else { 0.0 };
                keys.push(shared + structural + 0.05 * rng.normal_f32());
            }
        }
        let cc = spherical_kmeans(&keys, d, 2, 10, true, 4);
        let purity = |c: &Clustering| {
            let mut same = 0;
            for i in 0..64 {
                if (c.assign[i] == c.assign[0]) == (i % 2 == 0) {
                    same += 1;
                }
            }
            same.max(64 - same)
        };
        assert_eq!(purity(&cc), 64, "centered clustering must be pure");
    }
}
