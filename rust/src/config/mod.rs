//! Configuration: zone parameters (paper §5.1 defaults), model cost specs
//! (Llama3-8B-1048K, Qwen2.5-7B/72B, TinyLM), and hardware specs
//! (A100, A6000, PCIe 4.0, EPYC host) used by the live engine and `memsim`.

pub mod hardware;
pub mod model;

pub use hardware::{CapacityConfig, HardwareSpec};
pub use model::ModelSpec;

/// Zone / index configuration for the wave index (paper §5.1 defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct ZoneConfig {
    /// Sink tokens at the start of the context (steady zone).
    pub steady_sink: usize,
    /// Local-window tokens at the end of the context (steady zone).
    pub steady_local: usize,
    /// Average tokens per cluster (1 centroid / 16 tokens).
    pub tokens_per_cluster: usize,
    /// Fraction of clusters placed in the retrieval zone (1.8%).
    pub retrieval_frac: f64,
    /// Fraction of clusters placed in the estimation zone (23.2%).
    pub estimation_frac: f64,
    /// Segment length for build-time segmented clustering (8K).
    pub build_segment: usize,
    /// Segment length for incremental decode-time updates (1K).
    pub update_segment: usize,
    /// Spherical k-means iterations.
    pub kmeans_iters: usize,
    /// Apply the all-but-the-top centering technique before clustering.
    pub centering: bool,
}

impl Default for ZoneConfig {
    fn default() -> Self {
        ZoneConfig {
            steady_sink: 4,
            steady_local: 64,
            tokens_per_cluster: 16,
            retrieval_frac: 0.018,
            estimation_frac: 0.232,
            build_segment: 8192,
            update_segment: 1024,
            kmeans_iters: 10,
            centering: true,
        }
    }
}

impl ZoneConfig {
    /// Number of clusters for a segment of `seg_len` tokens.
    pub fn clusters_for_segment(&self, seg_len: usize) -> usize {
        (seg_len / self.tokens_per_cluster).max(1)
    }

    /// Retrieval-zone cluster count given a total cluster count.
    pub fn retrieval_clusters(&self, total_clusters: usize) -> usize {
        ((total_clusters as f64 * self.retrieval_frac).round() as usize).max(1)
    }

    /// Estimation-zone cluster count given a total cluster count.
    pub fn estimation_clusters(&self, total_clusters: usize) -> usize {
        (total_clusters as f64 * self.estimation_frac).round() as usize
    }

    /// Total steady-zone tokens.
    pub fn steady_tokens(&self) -> usize {
        self.steady_sink + self.steady_local
    }
}

/// Wave-buffer configuration (paper §5.1 defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct BufferConfig {
    /// KV block size in bytes (2 KB default).
    pub block_bytes: usize,
    /// GPU block-cache capacity as a fraction of all KV vectors (5%).
    pub cache_frac: f64,
    /// Cache replacement policy.
    pub policy: CachePolicy,
    /// CPU threads for the buffer manager (one NUMA node = 24 logical).
    pub cpu_threads: usize,
    /// Perform cache updates asynchronously off the critical path.
    pub async_update: bool,
    /// Disable the GPU block cache entirely ("Base" in Figure 16).
    pub gpu_cache_enabled: bool,
}

impl Default for BufferConfig {
    fn default() -> Self {
        BufferConfig {
            block_bytes: 2048,
            cache_frac: 0.05,
            policy: CachePolicy::Lru,
            cpu_threads: 4,
            async_update: true,
            gpu_cache_enabled: true,
        }
    }
}

/// Cache replacement policies supported by the wave buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CachePolicy {
    Lru,
    Fifo,
    Clock,
    TwoQ,
}

impl CachePolicy {
    pub fn parse(s: &str) -> Option<CachePolicy> {
        match s.to_ascii_lowercase().as_str() {
            "lru" => Some(CachePolicy::Lru),
            "fifo" => Some(CachePolicy::Fifo),
            "clock" => Some(CachePolicy::Clock),
            "2q" | "twoq" => Some(CachePolicy::TwoQ),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CachePolicy::Lru => "lru",
            CachePolicy::Fifo => "fifo",
            CachePolicy::Clock => "clock",
            CachePolicy::TwoQ => "2q",
        }
    }
}

/// Cold-tier spill codec selection (DESIGN.md §2 "Spill codecs"). Maps
/// 1:1 onto the per-page codec tags in `kvcache::spill`; `Exact` is the
/// default and keeps tiered serving bit-identical to a single-tier run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpillCodec {
    /// Bit-exact passthrough (lossless, 1.0× ratio).
    Exact,
    /// Group-wise int8 angle quantization (norms exact, ~0.47× at d=16).
    Int8,
    /// Group-wise int4 angle quantization (norms exact, ~0.35× at d=16).
    Int4,
    /// Low-rank K projection, V and positions exact (~0.75× at d=16).
    LowRankK,
}

impl SpillCodec {
    pub fn parse(s: &str) -> Option<SpillCodec> {
        match s.to_ascii_lowercase().as_str() {
            "exact" | "none" => Some(SpillCodec::Exact),
            "int8" | "int8-angle" => Some(SpillCodec::Int8),
            "int4" | "int4-angle" => Some(SpillCodec::Int4),
            "lowrank" | "lowrank-k" => Some(SpillCodec::LowRankK),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SpillCodec::Exact => "exact",
            SpillCodec::Int8 => "int8",
            SpillCodec::Int4 => "int4",
            SpillCodec::LowRankK => "lowrank",
        }
    }

    pub fn is_lossy(&self) -> bool {
        *self != SpillCodec::Exact
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zone_defaults_match_paper() {
        let z = ZoneConfig::default();
        assert_eq!(z.steady_tokens(), 68);
        // 128K context -> 8192 clusters -> ~147 retrieval clusters (~1.8%).
        let clusters = 128 * 1024 / z.tokens_per_cluster;
        assert_eq!(clusters, 8192);
        let r = z.retrieval_clusters(clusters);
        assert!((140..=155).contains(&r), "retrieval clusters {r}");
        let e = z.estimation_clusters(clusters);
        assert!((1850..=1950).contains(&e), "estimation clusters {e}");
    }

    #[test]
    fn cluster_count_rounds_up_to_one() {
        let z = ZoneConfig::default();
        assert_eq!(z.clusters_for_segment(8), 1);
        assert_eq!(z.clusters_for_segment(8192), 512);
    }

    #[test]
    fn cache_policy_parse_roundtrip() {
        for p in [CachePolicy::Lru, CachePolicy::Fifo, CachePolicy::Clock, CachePolicy::TwoQ] {
            assert_eq!(CachePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(CachePolicy::parse("arc"), None);
    }

    #[test]
    fn spill_codec_parse_roundtrip() {
        for c in [SpillCodec::Exact, SpillCodec::Int8, SpillCodec::Int4, SpillCodec::LowRankK] {
            assert_eq!(SpillCodec::parse(c.name()), Some(c));
        }
        assert_eq!(SpillCodec::parse("zstd"), None);
        assert!(!SpillCodec::Exact.is_lossy());
        assert!(SpillCodec::Int8.is_lossy());
    }
}
