//! Model cost specs. The live path runs TinyLM through PJRT; paper-scale
//! models are represented by their *dimensions* only — enough for `memsim`
//! to account bytes and flops exactly (KV cache size, attention reads,
//! GEMM flops), which is what the paper's throughput figures depend on.

/// Dimensional description of a transformer used for cost accounting.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub d_head: usize,
    pub ffn: usize,
    pub vocab: usize,
    /// Bytes per KV element (2 = fp16/bf16 as served in the paper).
    pub kv_bytes: usize,
    /// Bytes per weight element.
    pub w_bytes: usize,
    /// Number of GPUs the model is partitioned across (layer partitioning).
    pub n_gpus: usize,
}

impl ModelSpec {
    /// Llama3-8B-1048K (the paper's default model, single A100).
    pub fn llama3_8b() -> Self {
        ModelSpec {
            name: "llama3-8b-1048k",
            n_layers: 32,
            d_model: 4096,
            q_heads: 32,
            kv_heads: 8,
            d_head: 128,
            ffn: 14336,
            vocab: 128256,
            kv_bytes: 2,
            w_bytes: 2,
            n_gpus: 1,
        }
    }

    /// Llama3.1-8B — same dimensions as Llama3-8B (128K window).
    pub fn llama31_8b() -> Self {
        ModelSpec { name: "llama3.1-8b", ..Self::llama3_8b() }
    }

    /// Qwen2.5-7B.
    pub fn qwen25_7b() -> Self {
        ModelSpec {
            name: "qwen2.5-7b",
            n_layers: 28,
            d_model: 3584,
            q_heads: 28,
            kv_heads: 4,
            d_head: 128,
            ffn: 18944,
            vocab: 152064,
            kv_bytes: 2,
            w_bytes: 2,
            n_gpus: 1,
        }
    }

    /// Qwen2.5-72B partitioned across 8 GPUs (paper setup).
    pub fn qwen25_72b() -> Self {
        ModelSpec {
            name: "qwen2.5-72b",
            n_layers: 80,
            d_model: 8192,
            q_heads: 64,
            kv_heads: 8,
            d_head: 128,
            ffn: 29568,
            vocab: 152064,
            kv_bytes: 2,
            w_bytes: 2,
            n_gpus: 8,
        }
    }

    /// TinyLM — the live-path model (dimensions must match the manifest).
    pub fn tinylm() -> Self {
        ModelSpec {
            name: "tinylm",
            n_layers: 4,
            d_model: 256,
            q_heads: 8,
            kv_heads: 2,
            d_head: 32,
            ffn: 512,
            vocab: 256,
            kv_bytes: 4, // live path stores f32
            w_bytes: 4,
            n_gpus: 1,
        }
    }

    pub fn by_name(name: &str) -> Option<ModelSpec> {
        match name {
            "llama3-8b" | "llama3-8b-1048k" => Some(Self::llama3_8b()),
            "llama3.1-8b" => Some(Self::llama31_8b()),
            "qwen2.5-7b" => Some(Self::qwen25_7b()),
            "qwen2.5-72b" => Some(Self::qwen25_72b()),
            "tinylm" => Some(Self::tinylm()),
            _ => None,
        }
    }

    /// GQA group size (query heads per KV head).
    pub fn group(&self) -> usize {
        self.q_heads / self.kv_heads
    }

    /// KV-cache bytes for one token, all layers (K and V).
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.kv_heads * self.d_head * self.kv_bytes
    }

    /// Total KV-cache bytes for a batch of sequences of length `ctx`.
    pub fn kv_cache_bytes(&self, ctx: usize, batch: usize) -> usize {
        self.kv_bytes_per_token() * ctx * batch
    }

    /// Model weight bytes (approximate: attention + MLP + embeddings).
    pub fn weight_bytes(&self) -> usize {
        let attn = self.d_model * (self.q_heads + 2 * self.kv_heads) * self.d_head
            + self.q_heads * self.d_head * self.d_model;
        let mlp = 3 * self.d_model * self.ffn; // gate/up/down
        let per_layer = attn + mlp;
        let emb = 2 * self.vocab * self.d_model;
        (per_layer * self.n_layers + emb) * self.w_bytes
    }

    /// FLOPs of the non-attention part of one decode step for one sequence
    /// (projections + MLP + logits), 2 flops per MAC.
    pub fn decode_dense_flops(&self) -> f64 {
        let attn_proj = self.d_model as f64
            * ((self.q_heads + 2 * self.kv_heads) * self.d_head) as f64
            + (self.q_heads * self.d_head * self.d_model) as f64;
        let mlp = 3.0 * self.d_model as f64 * self.ffn as f64;
        let logits = self.d_model as f64 * self.vocab as f64;
        2.0 * ((attn_proj + mlp) * self.n_layers as f64 + logits)
    }

    /// FLOPs of exact attention over `n_tokens` KVs for one decode step,
    /// all layers (q·K plus a·V, per query head).
    pub fn attention_flops(&self, n_tokens: usize) -> f64 {
        2.0 * 2.0
            * (self.n_layers * self.q_heads * self.d_head) as f64
            * n_tokens as f64
    }

    /// Bytes read from memory for exact attention over `n_tokens` KVs
    /// (K and V, per KV head, all layers).
    pub fn attention_read_bytes(&self, n_tokens: usize) -> usize {
        2 * self.n_layers * self.kv_heads * self.d_head * self.kv_bytes * n_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama3_kv_cache_matches_paper() {
        // Paper §1: a 1M-token request with Llama3-8B needs ~125 GB.
        let m = ModelSpec::llama3_8b();
        let gb = m.kv_cache_bytes(1 << 20, 1) as f64 / 1e9;
        assert!((120.0..140.0).contains(&gb), "1M-token KV cache = {gb} GB");
    }

    #[test]
    fn a100_batch4_at_128k_fills_memory() {
        // Paper §2.2: A100 80GB supports max batch 4 at 128K for Llama3-8B.
        let m = ModelSpec::llama3_8b();
        let weights = m.weight_bytes() as f64 / 1e9;
        let kv4 = m.kv_cache_bytes(128 * 1024, 4) as f64 / 1e9;
        let kv5 = m.kv_cache_bytes(128 * 1024, 5) as f64 / 1e9;
        // batch 4 is right at the memory edge (the paper's max batch)...
        assert!((70.0..90.0).contains(&(weights + kv4)), "batch 4 edge: {}", weights + kv4);
        // ...and batch 5 is clearly out of memory.
        assert!(weights + kv5 > 85.0, "batch 5 OOMs: {}", weights + kv5);
    }

    #[test]
    fn group_sizes() {
        assert_eq!(ModelSpec::llama3_8b().group(), 4);
        assert_eq!(ModelSpec::qwen25_7b().group(), 7);
        assert_eq!(ModelSpec::qwen25_72b().group(), 8);
        assert_eq!(ModelSpec::tinylm().group(), 4);
    }

    #[test]
    fn by_name_lookup() {
        assert!(ModelSpec::by_name("llama3-8b").is_some());
        assert!(ModelSpec::by_name("nope").is_none());
    }

    #[test]
    fn weight_bytes_order_of_magnitude() {
        // Llama3-8B has ~8B params at 2 bytes => ~16 GB.
        let gb = ModelSpec::llama3_8b().weight_bytes() as f64 / 1e9;
        assert!((12.0..20.0).contains(&gb), "weights = {gb} GB");
    }
}
