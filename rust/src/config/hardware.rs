//! Hardware specs used by the analytic simulator: device compute/bandwidth
//! parameters calibrated to the paper's testbed numbers (§2.2, §5.1) —
//! plus the serving-capacity knobs ([`CapacityConfig`]) that bound the
//! KV arena inside a hardware budget (DESIGN.md §2 "Admission & quotas").

/// A device-level hardware description (GPU + host + interconnect).
#[derive(Clone, Debug, PartialEq)]
pub struct HardwareSpec {
    pub name: &'static str,
    /// GPU HBM capacity in bytes.
    pub gpu_mem_bytes: usize,
    /// GPU HBM bandwidth, bytes/s.
    pub gpu_bw: f64,
    /// GPU dense compute throughput, flops/s (fp16/bf16 tensor).
    pub gpu_flops: f64,
    /// PCIe unidirectional bandwidth, bytes/s.
    pub pcie_bw: f64,
    /// Cold-spill-tier sequential bandwidth, bytes/s (NVMe-class store
    /// behind the DRAM KV tier — the level below the paper's hierarchy,
    /// used by the tiered-arena term in `memsim`).
    pub spill_bw: f64,
    /// Host DRAM capacity in bytes.
    pub cpu_mem_bytes: usize,
    /// Host memory bandwidth available to the serving process, bytes/s.
    pub cpu_bw: f64,
    /// Host fp32 compute throughput, flops/s (one NUMA node).
    pub cpu_flops: f64,
    /// Fixed kernel-launch / driver overhead per GPU kernel, seconds.
    pub kernel_launch_s: f64,
    /// Fixed cost to initiate one PCIe DMA transfer, seconds.
    pub pcie_latency_s: f64,
}

impl HardwareSpec {
    /// NVIDIA A100 80GB + AMD EPYC 7V12 host over PCIe 4.0 x16
    /// (the paper's testbed; HBM/PCIe ratio ~ 60x, §2.3).
    pub fn a100() -> Self {
        HardwareSpec {
            name: "a100",
            gpu_mem_bytes: 80 * (1 << 30),
            gpu_bw: 2.039e12,   // 2039 GB/s HBM2e
            gpu_flops: 312e12,  // bf16 tensor core
            pcie_bw: 32e9,      // PCIe 4.0 x16 unidirectional
            spill_bw: 7e9,      // PCIe 4.0 x4 NVMe sequential read
            cpu_mem_bytes: 1700 * (1 << 30),
            cpu_bw: 80e9,       // one NUMA node of EPYC 7V12
            cpu_flops: 1.2e12,  // 12 cores * AVX2 fp32
            kernel_launch_s: 5e-6,
            pcie_latency_s: 10e-6,
        }
    }

    /// NVIDIA RTX A6000 48GB (Figure 18 cross-hardware point).
    pub fn a6000() -> Self {
        HardwareSpec {
            name: "a6000",
            gpu_mem_bytes: 48 * (1 << 30),
            gpu_bw: 768e9,
            gpu_flops: 155e12,
            pcie_bw: 32e9,
            spill_bw: 7e9,
            cpu_mem_bytes: 1700 * (1 << 30),
            cpu_bw: 80e9,
            cpu_flops: 1.2e12,
            kernel_launch_s: 5e-6,
            pcie_latency_s: 10e-6,
        }
    }

    pub fn by_name(name: &str) -> Option<HardwareSpec> {
        match name {
            "a100" => Some(Self::a100()),
            "a6000" => Some(Self::a6000()),
            _ => None,
        }
    }

    /// Time to stream `bytes` through GPU HBM.
    pub fn gpu_stream_s(&self, bytes: usize) -> f64 {
        bytes as f64 / self.gpu_bw
    }

    /// Time to move `bytes` over PCIe in one DMA.
    pub fn pcie_s(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.pcie_latency_s + bytes as f64 / self.pcie_bw
        }
    }

    /// GPU time for `flops` of dense work at `eff` MFU.
    pub fn gpu_compute_s(&self, flops: f64, eff: f64) -> f64 {
        flops / (self.gpu_flops * eff)
    }

    /// HBM : PCIe bandwidth ratio (the paper's ~60x, §2.3).
    pub fn hbm_pcie_ratio(&self) -> f64 {
        self.gpu_bw / self.pcie_bw
    }
}

/// Serving-capacity knobs: the byte budget the KV block arena may
/// occupy, an optional per-tenant quota, and the admission gate's
/// tuning. `None` means unbounded (the single-tenant dev default —
/// exactly the pre-cap behaviour).
#[derive(Clone, Debug, PartialEq)]
pub struct CapacityConfig {
    /// Hard cap on arena-resident KV bytes (live + free-list).
    pub arena_capacity_bytes: Option<usize>,
    /// Per-tenant cap on live KV bytes.
    pub tenant_quota_bytes: Option<usize>,
    /// Fraction of the capacity the admission gate holds back so
    /// decode-time growth of already-admitted sessions cannot hit the
    /// cap.
    pub admit_headroom_frac: f64,
    /// Multiplier on the analytic block-footprint estimate (cluster
    /// tail-block fragmentation: clusters never share blocks).
    pub est_fudge: f64,
}

impl Default for CapacityConfig {
    fn default() -> Self {
        CapacityConfig {
            arena_capacity_bytes: None,
            tenant_quota_bytes: None,
            admit_headroom_frac: 0.2,
            est_fudge: 1.5,
        }
    }
}

impl CapacityConfig {
    /// Unbounded config (explicit-name alias of `Default`).
    pub fn unbounded() -> Self {
        Self::default()
    }

    /// Budget the arena at `cpu_frac` of the host's DRAM (the paper
    /// places the KV store in CPU memory; the serving process cannot
    /// take all of it).
    pub fn for_hardware(hw: &HardwareSpec, cpu_frac: f64) -> Self {
        CapacityConfig {
            arena_capacity_bytes: Some((hw.cpu_mem_bytes as f64 * cpu_frac) as usize),
            ..Self::default()
        }
    }

    /// Arena capacity in whole blocks of `block_bytes` (minimum one).
    pub fn capacity_blocks(&self, block_bytes: usize) -> Option<usize> {
        self.arena_capacity_bytes.map(|b| (b / block_bytes.max(1)).max(1))
    }

    /// Tenant quota in whole blocks of `block_bytes` (minimum one).
    pub fn quota_blocks(&self, block_bytes: usize) -> Option<usize> {
        self.tenant_quota_bytes.map(|b| (b / block_bytes.max(1)).max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_defaults_unbounded() {
        let c = CapacityConfig::default();
        assert_eq!(c.capacity_blocks(2048), None);
        assert_eq!(c.quota_blocks(2048), None);
        assert!(c.admit_headroom_frac > 0.0 && c.admit_headroom_frac < 1.0);
        assert!(c.est_fudge >= 1.0);
        assert_eq!(c, CapacityConfig::unbounded());
    }

    #[test]
    fn capacity_blocks_round_down() {
        let c = CapacityConfig {
            arena_capacity_bytes: Some(10_000),
            tenant_quota_bytes: Some(2048),
            ..CapacityConfig::default()
        };
        assert_eq!(c.capacity_blocks(2048), Some(4));
        assert_eq!(c.quota_blocks(2048), Some(1));
        // sub-block budgets clamp to one block rather than zero
        let tiny = CapacityConfig {
            arena_capacity_bytes: Some(100),
            ..CapacityConfig::default()
        };
        assert_eq!(tiny.capacity_blocks(2048), Some(1));
    }

    #[test]
    fn for_hardware_budgets_host_dram() {
        let hw = HardwareSpec::a100();
        let c = CapacityConfig::for_hardware(&hw, 0.5);
        assert_eq!(c.arena_capacity_bytes, Some(hw.cpu_mem_bytes / 2));
        // paper testbed: 850 GB budget -> ~445M 2KB blocks
        let blocks = c.capacity_blocks(2048).unwrap();
        assert!(blocks > 100_000_000, "blocks = {blocks}");
    }

    #[test]
    fn a100_ratio_matches_paper() {
        let hw = HardwareSpec::a100();
        let r = hw.hbm_pcie_ratio();
        assert!((55.0..70.0).contains(&r), "HBM/PCIe ratio = {r}");
    }

    #[test]
    fn pcie_includes_fixed_latency() {
        let hw = HardwareSpec::a100();
        assert_eq!(hw.pcie_s(0), 0.0);
        assert!(hw.pcie_s(1) >= hw.pcie_latency_s);
        // 32 MB at 32 GB/s ~ 1 ms.
        let t = hw.pcie_s(32 << 20);
        assert!((0.9e-3..1.3e-3).contains(&t), "32MB transfer = {t}s");
    }

    #[test]
    fn sparsity_break_even_requires_98pct() {
        // Paper §2.3: hiding PCIe latency needs >98% sparsity — the
        // fraction of bytes NOT moved must exceed 1 - pcie/hbm.
        let hw = HardwareSpec::a100();
        let needed = 1.0 - hw.pcie_bw / hw.gpu_bw;
        assert!(needed > 0.98, "required sparsity = {needed}");
    }
}
