//! # RetroInfer
//!
//! A from-scratch reproduction of *"RetroInfer: A Vector Storage Engine for
//! Scalable Long-Context LLM Inference"* (PVLDB'26) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **wave index** ([`index`]) — attention-aware clustered vector index:
//!   tripartite attention approximation, accuracy-bound estimation,
//!   segmented clustering, incremental updates.
//! * **wave buffer** ([`buffer`], [`kvcache`]) — accuracy-agnostic GPU/CPU
//!   buffer manager: cluster mapping table, block cache, execution-buffer
//!   assembly, asynchronous cache update.
//! * **coordinator** ([`coordinator`], [`engine`]) — request router,
//!   continuous batcher, prefill/decode scheduler.
//! * **runtime** ([`runtime`]) — loads the AOT-compiled HLO artifacts
//!   produced by `python/compile/aot.py` and executes them on the PJRT CPU
//!   client (the `xla` crate). Python never runs on the request path.
//! * **memsim** ([`memsim`]) — analytic A100/PCIe hardware model replaying
//!   real block traces for paper-scale throughput figures.
//! * **baselines** ([`baselines`]) — Quest, MagicPIG, InfiniGen, PQCache,
//!   StreamingLLM and full attention, re-implemented over the same
//!   KV substrate.
//!
//! See DESIGN.md for the experiment index and substitutions, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod attention;
pub mod baselines;
pub mod buffer;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod index;
pub mod kernels;
pub mod kvcache;
pub mod memsim;
pub mod metrics;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod workload;
