//! Minimal CLI argument parser substrate (no `clap` available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands, with typed getters and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    /// If `with_subcommand` is set, the first non-flag token becomes the
    /// subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(args: I, with_subcommand: bool) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    out.flags
                        .insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else if with_subcommand && out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(with_subcommand: bool) -> Args {
        Args::parse(std::env::args().skip(1), with_subcommand)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got `{v}`"))
            })
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got `{v}`"))
            })
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{key} expects a number, got `{v}`"))
            })
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key)
            .map(|v| matches!(v, "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    /// Comma-separated list of usize (`--batches 1,2,4`).
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        self.get(key)
            .map(|v| {
                v.split(',')
                    .map(|x| {
                        x.trim()
                            .parse()
                            .unwrap_or_else(|_| panic!("--{key}: bad integer `{x}`"))
                    })
                    .collect()
            })
            .unwrap_or_else(|| default.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn flags_and_values() {
        let a = Args::parse(argv("--x 3 --flag --name=foo pos1"), false);
        assert_eq!(a.usize_or("x", 0), 3);
        assert!(a.bool_or("flag", false));
        assert_eq!(a.get("name"), Some("foo"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn subcommand() {
        let a = Args::parse(argv("serve --port 80"), true);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize_or("port", 0), 80);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv(""), false);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.f64_or("missing", 0.5), 0.5);
        assert_eq!(a.str_or("missing", "d"), "d");
    }

    #[test]
    fn usize_list() {
        let a = Args::parse(argv("--b 1,2,8"), false);
        assert_eq!(a.usize_list_or("b", &[]), vec![1, 2, 8]);
        assert_eq!(a.usize_list_or("c", &[4]), vec![4]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(argv("--quick --n 5"), false);
        assert!(a.bool_or("quick", false));
        assert_eq!(a.usize_or("n", 0), 5);
    }
}
