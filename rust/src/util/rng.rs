//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! SplitMix64 for seeding, xoshiro256** as the main generator, plus the
//! distribution helpers the workload generators need (uniform, normal via
//! Box–Muller, Poisson, exponential, shuffles, choice without replacement).

/// xoshiro256** seeded via SplitMix64. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-head / per-request rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Exponential with rate `lambda` (inter-arrival times for Poisson loads).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let mut u = self.f64();
        if u <= f64::MIN_POSITIVE {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / lambda
    }

    /// Poisson-distributed count (Knuth for small means, normal approx above).
    pub fn poisson(&mut self, mean: f64) -> usize {
        if mean > 30.0 {
            let v = mean + mean.sqrt() * self.normal();
            return v.max(0.0).round() as usize;
        }
        let l = (-mean).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices out of `n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Vector of standard-normal f32.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(13);
        for &mean in &[0.5, 4.0, 50.0] {
            let n = 20_000;
            let total: usize = (0..n).map(|_| r.poisson(mean)).sum();
            let got = total as f64 / n as f64;
            assert!((got - mean).abs() < mean.max(1.0) * 0.1, "mean={mean} got={got}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let total: f64 = (0..n).map(|_| r.exponential(2.0)).sum();
        assert!((total / n as f64 - 0.5).abs() < 0.02);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
