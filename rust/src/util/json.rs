//! Minimal JSON substrate (no `serde` available offline).
//!
//! A tolerant recursive-descent parser plus an emitter, sufficient for the
//! artifact manifest produced by `python/compile/aot.py`, experiment result
//! files, and engine configuration.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------------
    // Accessors
    // ------------------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that panics with a useful message (manifests are
    /// trusted build outputs; missing fields are programmer errors).
    pub fn field(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json field `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn usize_field(&self, key: &str) -> usize {
        self.field(key)
            .as_usize()
            .unwrap_or_else(|| panic!("json field `{key}` is not a number"))
    }

    pub fn f64_field(&self, key: &str) -> f64 {
        self.field(key)
            .as_f64()
            .unwrap_or_else(|| panic!("json field `{key}` is not a number"))
    }

    pub fn str_field(&self, key: &str) -> &str {
        self.field(key)
            .as_str()
            .unwrap_or_else(|| panic!("json field `{key}` is not a string"))
    }

    pub fn arr_field(&self, key: &str) -> &[Json] {
        self.field(key)
            .as_arr()
            .unwrap_or_else(|| panic!("json field `{key}` is not an array"))
    }

    pub fn usize_vec(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default()
    }

    // ------------------------------------------------------------------
    // Builders
    // ------------------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ------------------------------------------------------------------
    // Emit
    // ------------------------------------------------------------------
    pub fn emit(&self) -> String {
        let mut out = String::new();
        self.emit_into(&mut out);
        out
    }

    fn emit_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.emit_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).emit_into(out);
                    out.push(':');
                    v.emit_into(out);
                }
                out.push('}');
            }
        }
    }
}

// ----------------------------------------------------------------------
// Parser
// ----------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-3", "2.5", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.emit()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": -1.5e2}"#).unwrap();
        assert_eq!(v.field("c").as_f64(), Some(-150.0));
        let arr = v.arr_field("a");
        assert_eq!(arr[2].str_field("b"), "x\ny");
    }

    #[test]
    fn emit_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.emit()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("nulll").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(5.0).emit(), "5");
        assert_eq!(Json::Num(5.5).emit(), "5.5");
    }

    #[test]
    fn big_roundtrip() {
        let mut m = BTreeMap::new();
        for i in 0..100 {
            m.insert(format!("k{i}"), Json::Num(i as f64 * 0.5));
        }
        let v = Json::Obj(m);
        assert_eq!(parse(&v.emit()).unwrap(), v);
    }
}
