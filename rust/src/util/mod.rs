//! Hand-rolled substrates (the offline image has no tokio/serde/clap/
//! criterion/proptest/rand — DESIGN.md §1 documents the substitutions).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
