//! Mini property-testing substrate (no `proptest` available offline).
//!
//! Deterministic, seed-enumerated case generation with shrinking-lite:
//! on failure, report the seed so the case reproduces exactly. Invariant
//! tests over the coordinator/index/cache use `check` with generator
//! closures built on [`crate::util::rng::Rng`].

use super::rng::Rng;

/// Run `cases` randomized trials of `prop`. Each trial gets an `Rng` with a
/// distinct, reportable seed. On failure, panics with the offending seed
/// (re-run with `check_one(seed, prop)` to reproduce).
pub fn check<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, cases: usize, prop: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Reproduce a single failing case by seed.
pub fn check_one<F: Fn(&mut Rng) -> Result<(), String>>(seed: u64, prop: F) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

/// Assert-style helpers that return `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// `prop_assert_eq!(a, b)` with value reporting.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("sum-commutes", 50, |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            prop_assert_eq!(a + b, b + a);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "seed")]
    fn failing_property_reports_seed() {
        check("always-fails", 3, |_| Err("nope".to_string()));
    }

    #[test]
    fn check_one_reproduces() {
        // find a failing seed, then reproduce it
        let prop = |rng: &mut Rng| -> Result<(), String> {
            let v = rng.below(10);
            prop_assert!(v != 3, "hit 3");
            Ok(())
        };
        let mut failing = None;
        for case in 0..200u64 {
            let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15);
            let mut rng = Rng::new(seed);
            if prop(&mut rng).is_err() {
                failing = Some(seed);
                break;
            }
        }
        let seed = failing.expect("some seed should hit 3");
        let res = std::panic::catch_unwind(|| check_one(seed, prop));
        assert!(res.is_err());
    }
}
