//! Statistics substrate: online moments, percentiles, histograms.
//! Used by metrics, the bench harness and the hardware simulator.

/// Online mean/variance via Welford's algorithm.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a stored sample (fine for bench-scale data).
#[derive(Clone, Debug, Default)]
pub struct Sample {
    xs: Vec<f64>,
    sorted: bool,
}

impl Sample {
    pub fn new() -> Self {
        Sample::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let rank = (p / 100.0) * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        if self.xs.len() < 2 {
            return 0.0;
        }
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.first().copied().unwrap_or(f64::NAN)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.last().copied().unwrap_or(f64::NAN)
    }
}

/// Fixed-bucket histogram over a [lo, hi) range with overflow buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    below: u64,
    above: u64,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_buckets: usize) -> Self {
        assert!(hi > lo && n_buckets > 0);
        Histogram { lo, hi, buckets: vec![0; n_buckets], below: 0, above: 0, count: 0 }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.below += 1;
        } else if x >= self.hi {
            self.above += 1;
        } else {
            let i = ((x - self.lo) / (self.hi - self.lo) * self.buckets.len() as f64) as usize;
            let last = self.buckets.len() - 1;
            self.buckets[i.min(last)] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    /// Fraction of samples at or below the upper edge of bucket `i`.
    pub fn cdf_at(&self, i: usize) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let cum: u64 = self.below + self.buckets[..=i].iter().sum::<u64>();
        cum as f64 / self.count as f64
    }
}

/// Cosine similarity between two equal-length vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for i in 0..a.len() {
        dot += a[i] as f64 * b[i] as f64;
        na += (a[i] as f64).powi(2);
        nb += (b[i] as f64).powi(2);
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Relative L2 error ||a-b|| / ||b||.
pub fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut diff, mut norm) = (0.0f64, 0.0f64);
    for i in 0..a.len() {
        diff += (a[i] as f64 - b[i] as f64).powi(2);
        norm += (b[i] as f64).powi(2);
    }
    if norm == 0.0 {
        return if diff == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (diff / norm).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0f64).powi(2)).sum::<f64>() / 4.0;
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Sample::new();
        for i in 0..=100 {
            s.add(i as f64);
        }
        assert!((s.percentile(0.0) - 0.0).abs() < 1e-9);
        assert!((s.percentile(50.0) - 50.0).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(100.0);
        assert_eq!(h.count(), 12);
        assert!(h.bucket_counts().iter().all(|&c| c == 1));
        assert!((h.cdf_at(9) - 11.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_basic() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn rel_err_basic() {
        assert_eq!(rel_err(&[1.0, 1.0], &[1.0, 1.0]), 0.0);
        let e = rel_err(&[1.1, 1.0], &[1.0, 1.0]);
        assert!((e - (0.01f64 / 2.0).sqrt()).abs() < 1e-6);
    }
}
