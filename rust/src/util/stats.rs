//! Statistics substrate: online moments, percentiles, histograms.
//! Used by metrics, the bench harness and the hardware simulator.

/// Online mean/variance via Welford's algorithm.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a stored sample (fine for bench-scale data).
#[derive(Clone, Debug, Default)]
pub struct Sample {
    xs: Vec<f64>,
    sorted: bool,
}

impl Sample {
    pub fn new() -> Self {
        Sample::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let rank = (p / 100.0) * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        if self.xs.len() < 2 {
            return 0.0;
        }
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.first().copied().unwrap_or(f64::NAN)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.last().copied().unwrap_or(f64::NAN)
    }
}

/// Fixed-bucket histogram over a [lo, hi) range with overflow buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    below: u64,
    above: u64,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_buckets: usize) -> Self {
        assert!(hi > lo && n_buckets > 0);
        Histogram { lo, hi, buckets: vec![0; n_buckets], below: 0, above: 0, count: 0 }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.below += 1;
        } else if x >= self.hi {
            self.above += 1;
        } else {
            let i = ((x - self.lo) / (self.hi - self.lo) * self.buckets.len() as f64) as usize;
            let last = self.buckets.len() - 1;
            self.buckets[i.min(last)] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    /// Fraction of samples at or below the upper edge of bucket `i`.
    pub fn cdf_at(&self, i: usize) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let cum: u64 = self.below + self.buckets[..=i].iter().sum::<u64>();
        cum as f64 / self.count as f64
    }
}

/// Streaming percentile histogram with log-spaced buckets: O(buckets)
/// memory regardless of how many observations arrive, so unbounded
/// online series (per-token latencies over hours of serving) never grow
/// the way [`Sample`]'s stored vector does. Buckets are geometric —
/// `per_decade` buckets per power of ten — which bounds the *relative*
/// error of a reported percentile by one bucket width
/// (`10^(1/per_decade) - 1`), the natural error model for latencies.
/// Exact min/max are tracked on the side so the tails clamp truthfully.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    /// Lower edge of bucket 0.
    lo: f64,
    per_decade: usize,
    buckets: Vec<u64>,
    below: u64,
    above: u64,
    count: u64,
    min: f64,
    max: f64,
}

impl LogHistogram {
    /// Buckets spanning `[lo, lo * 10^decades)`.
    pub fn new(lo: f64, decades: usize, per_decade: usize) -> Self {
        assert!(lo > 0.0 && decades > 0 && per_decade > 0);
        LogHistogram {
            lo,
            per_decade,
            buckets: vec![0; decades * per_decade],
            below: 0,
            above: 0,
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Geometry for serving latencies in seconds: 1µs .. 1000s at ~6%
    /// relative resolution (9 decades × 40 buckets = 360 slots).
    pub fn latency_s() -> Self {
        LogHistogram::new(1e-6, 9, 40)
    }

    /// Record one observation. Non-finite values are dropped (a NaN
    /// latency is a bug upstream, not a data point).
    pub fn observe(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        self.count += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if x < self.lo {
            self.below += 1;
            return;
        }
        let i = ((x / self.lo).log10() * self.per_decade as f64).floor() as usize;
        match self.buckets.get_mut(i) {
            Some(b) => *b += 1,
            None => self.above += 1,
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.max }
    }

    fn edge(&self, i: usize) -> f64 {
        self.lo * 10f64.powf(i as f64 / self.per_decade as f64)
    }

    /// Percentile estimate, `p` in [0, 100]: cumulative walk to the
    /// target rank, geometric interpolation inside the landing bucket,
    /// clamped to the exact observed [min, max].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let p = p.clamp(0.0, 100.0);
        if p == 0.0 {
            return self.min;
        }
        let target = (((p / 100.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = self.below;
        if target <= cum {
            return self.min;
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let prev = cum;
            cum += c;
            if target <= cum {
                let frac = (target - prev) as f64 / c as f64;
                let v = self.edge(i) * (self.edge(i + 1) / self.edge(i)).powf(frac);
                return v.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold another histogram of identical geometry into this one —
    /// cross-replica aggregation for cluster-level percentiles.
    pub fn merge(&mut self, other: &LogHistogram) {
        assert_eq!(self.lo, other.lo, "merge requires identical geometry");
        assert_eq!(self.per_decade, other.per_decade);
        assert_eq!(self.buckets.len(), other.buckets.len());
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.below += other.below;
        self.above += other.above;
        self.count += other.count;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Cosine similarity between two equal-length vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for i in 0..a.len() {
        dot += a[i] as f64 * b[i] as f64;
        na += (a[i] as f64).powi(2);
        nb += (b[i] as f64).powi(2);
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Relative L2 error ||a-b|| / ||b||.
pub fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut diff, mut norm) = (0.0f64, 0.0f64);
    for i in 0..a.len() {
        diff += (a[i] as f64 - b[i] as f64).powi(2);
        norm += (b[i] as f64).powi(2);
    }
    if norm == 0.0 {
        return if diff == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (diff / norm).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0f64).powi(2)).sum::<f64>() / 4.0;
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Sample::new();
        for i in 0..=100 {
            s.add(i as f64);
        }
        assert!((s.percentile(0.0) - 0.0).abs() < 1e-9);
        assert!((s.percentile(50.0) - 50.0).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(100.0);
        assert_eq!(h.count(), 12);
        assert!(h.bucket_counts().iter().all(|&c| c == 1));
        assert!((h.cdf_at(9) - 11.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn log_histogram_percentiles_within_bucket_error() {
        let mut h = LogHistogram::latency_s();
        // 1..=1000 ms, uniformly
        for i in 1..=1000 {
            h.observe(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 1000);
        let tol = 10f64.powf(1.0 / 40.0); // one bucket of relative error
        for (p, exact) in [(50.0, 0.5), (95.0, 0.95), (99.0, 0.99)] {
            let est = h.percentile(p);
            assert!(
                est / exact < tol && exact / est < tol,
                "p{p}: {est} vs {exact} (tol {tol})"
            );
        }
        // exact tails
        assert_eq!(h.percentile(0.0), 1e-3);
        assert_eq!(h.percentile(100.0), 1.0);
        assert_eq!(h.min(), 1e-3);
        assert_eq!(h.max(), 1.0);
    }

    #[test]
    fn log_histogram_memory_does_not_grow() {
        let mut h = LogHistogram::new(1e-6, 3, 8);
        let before = h.buckets.len();
        for i in 0..100_000 {
            h.observe(1e-6 * (1.0 + (i % 997) as f64));
        }
        assert_eq!(h.buckets.len(), before, "observation never allocates");
        assert_eq!(h.count(), 100_000);
    }

    #[test]
    fn log_histogram_out_of_range_and_nonfinite() {
        let mut h = LogHistogram::new(1e-3, 3, 4); // [1ms, 1s)
        h.observe(1e-6); // below
        h.observe(50.0); // above
        h.observe(0.1);
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        assert_eq!(h.count(), 3, "non-finite observations are dropped");
        assert_eq!(h.percentile(0.0), 1e-6, "below-range clamps to exact min");
        assert_eq!(h.percentile(100.0), 50.0, "above-range clamps to exact max");
        assert!(h.percentile(50.0) > 0.05 && h.percentile(50.0) < 0.2);
        assert!(LogHistogram::latency_s().percentile(50.0).is_nan());
    }

    #[test]
    fn log_histogram_merge_matches_combined_stream() {
        let (mut a, mut b, mut all) =
            (LogHistogram::latency_s(), LogHistogram::latency_s(), LogHistogram::latency_s());
        for i in 1..=200 {
            let x = i as f64 * 2.5e-3;
            if i % 2 == 0 { a.observe(x) } else { b.observe(x) }
            all.observe(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        for p in [10.0, 50.0, 90.0, 99.0] {
            assert_eq!(a.percentile(p), all.percentile(p), "merge is exact at p{p}");
        }
    }

    #[test]
    fn cosine_basic() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn rel_err_basic() {
        assert_eq!(rel_err(&[1.0, 1.0], &[1.0, 1.0]), 0.0);
        let e = rel_err(&[1.1, 1.0], &[1.0, 1.0]);
        assert!((e - (0.01f64 / 2.0).sqrt()).abs() < 1e-6);
    }
}
