//! Bench harness substrate (no `criterion` available offline).
//!
//! `cargo bench` targets in `benches/` use `harness = false` and drive this
//! module: warmup, timed iterations, mean/σ/percentiles, and a
//! paper-figure-style table printer shared by all experiment benches.

use std::time::Instant;

use super::stats::Sample;

/// Result of one timed benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` with adaptive iteration count (targets ~`budget_ms` of runtime
/// after `warmup` calls). Returns per-iteration stats.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, budget_ms: f64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // estimate cost
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_ms / 1e3 / est) as usize).clamp(3, 10_000);

    let mut sample = Sample::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        sample.add(t.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: sample.mean(),
        std_ns: sample.std(),
        p50_ns: sample.percentile(50.0),
        p99_ns: sample.percentile(99.0),
    }
}

pub fn print_result(r: &BenchResult) {
    println!(
        "{:<44} {:>10.1} us/iter  (σ {:>8.1}, p50 {:>9.1}, p99 {:>9.1}, n={})",
        r.name,
        r.mean_ns / 1e3,
        r.std_ns / 1e3,
        r.p50_ns / 1e3,
        r.p99_ns / 1e3,
        r.iters
    );
}

/// Fixed-width table printer for paper-style figures: a header row then
/// data rows, column-aligned.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:>width$}", c, width = widths[i]));
            }
            println!("{s}");
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            line(row);
        }
    }
}

/// `RI_QUICK=1` shrinks experiment sizes for CI-style smoke runs.
pub fn quick_mode() -> bool {
    std::env::var("RI_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Formats a float with engineering-style precision for tables.
pub fn fmt(v: f64) -> String {
    if !v.is_finite() {
        return "-".into();
    }
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else if v.abs() >= 0.1 {
        format!("{v:.3}")
    } else {
        format!("{v:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_timing() {
        let r = bench("noop-ish", 1, 5.0, || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(vec!["1".into()]);
        }));
        assert!(res.is_err());
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(1234.0), "1234");
        assert_eq!(fmt(12.34), "12.3");
        assert_eq!(fmt(0.5), "0.500");
        assert!(fmt(0.001).contains('e'));
        assert_eq!(fmt(f64::NAN), "-");
    }
}
