//! Thread-pool substrate (no `tokio`/`rayon` available offline).
//!
//! A fixed pool of workers consuming boxed jobs from a shared queue.
//! Used by the wave buffer for asynchronous cache updates (paper §4.3:
//! "cache updates are decoupled from cache access ... performed
//! asynchronously by the CPU, in parallel with the data copy and
//! attention computation"), by the engine's per-head execution-buffer
//! fan-out ([`ThreadPool::scope_for_each`]) and by experiment harnesses
//! for parallel trials.
//!
//! The pool has two lanes: the compute lane (`submit`, the scoped
//! fan-outs) and a dedicated I/O lane (`submit_io`) with its own queue
//! and worker(s). Spill-page reads ride the I/O lane so a backlog of
//! slow cold-tier reads can never occupy compute workers, and a
//! compute fan-out can never delay the staging reads it is waiting to
//! overlap with. `wait_idle` remains a barrier over BOTH lanes.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    /// dedicated I/O lane: its own queue + condvar, drained only by
    /// the I/O worker(s) — compute workers never pull from it
    io_queue: Mutex<VecDeque<Job>>,
    io_available: Condvar,
    /// jobs submitted but not yet finished, across BOTH lanes
    in_flight: AtomicUsize,
    /// I/O-lane jobs submitted but not yet finished (diagnostics)
    io_in_flight: AtomicUsize,
    done: Condvar,
    shutdown: Mutex<bool>,
    /// jobs that panicked (workers survive; scopes turn this into a
    /// caller-side panic so failures cannot be silently swallowed)
    panicked: AtomicUsize,
}

/// Fixed-size worker pool with a `wait_idle` barrier and a dedicated
/// I/O lane ([`ThreadPool::submit_io`]).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    io_workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// `n_threads` compute workers plus one dedicated I/O worker.
    pub fn new(n_threads: usize) -> Self {
        Self::with_io_threads(n_threads, 1)
    }

    /// `n_threads` compute workers plus `io_threads` dedicated I/O
    /// workers (min 1 each — `submit_io` must always make progress).
    pub fn with_io_threads(n_threads: usize, io_threads: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            io_queue: Mutex::new(VecDeque::new()),
            io_available: Condvar::new(),
            in_flight: AtomicUsize::new(0),
            io_in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
            shutdown: Mutex::new(false),
            panicked: AtomicUsize::new(0),
        });
        let workers = (0..n_threads.max(1))
            .map(|_| {
                let s = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(s, Lane::Compute))
            })
            .collect();
        let io_workers = (0..io_threads.max(1))
            .map(|_| {
                let s = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(s, Lane::Io))
            })
            .collect();
        ThreadPool { shared, workers, io_workers }
    }

    /// Enqueue a job for asynchronous execution on the compute lane.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(f));
        }
        self.shared.available.notify_one();
    }

    /// Enqueue a job on the dedicated I/O lane. I/O jobs are drained
    /// only by the I/O worker(s): a backlog here can never starve the
    /// compute lane, and compute fan-outs can never delay it. Covered
    /// by the same `wait_idle` barrier as compute jobs.
    pub fn submit_io<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.shared.io_in_flight.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.io_queue.lock().unwrap();
            q.push_back(Box::new(f));
        }
        self.shared.io_available.notify_one();
    }

    /// Block until every submitted job — compute AND I/O lane — has
    /// completed.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.queue.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    pub fn pending(&self) -> usize {
        self.shared.in_flight.load(Ordering::SeqCst)
    }

    /// I/O-lane jobs submitted but not yet finished.
    pub fn io_pending(&self) -> usize {
        self.shared.io_in_flight.load(Ordering::SeqCst)
    }

    pub fn n_threads(&self) -> usize {
        self.workers.len()
    }

    pub fn n_io_threads(&self) -> usize {
        self.io_workers.len()
    }

    /// Run a closure over every index in `0..n` across the pool, blocking
    /// until all are done (scoped-parallel map for experiment harnesses).
    pub fn scoped_for_each<F: Fn(usize) + Send + Sync + 'static>(&self, n: usize, f: Arc<F>) {
        for i in 0..n {
            let f = Arc::clone(&f);
            self.submit(move || f(i));
        }
        self.wait_idle();
    }

    /// Jobs that panicked since the pool was created.
    pub fn panicked(&self) -> usize {
        self.shared.panicked.load(Ordering::SeqCst)
    }

    /// Borrow-friendly scoped fan-out: run `f(i)` for every `i in 0..n`
    /// across the pool and return once *these* jobs (not the whole
    /// queue) have completed. Unlike [`ThreadPool::scoped_for_each`],
    /// `f` may borrow the caller's stack — the decode hot path fans
    /// per-(sequence, head) execution-buffer assembly out through here
    /// with borrowed session state.
    ///
    /// Panics if any job panicked. Must not be called from a pool
    /// worker (the scope would wait on jobs that can be queued behind
    /// itself).
    pub fn scope_for_each<F: Fn(usize) + Sync>(&self, n: usize, f: &F) {
        if n == 0 {
            return;
        }
        let scope = Arc::new(Scope {
            remaining: Mutex::new(n),
            done: Condvar::new(),
            job_panicked: AtomicBool::new(false),
        });
        // SAFETY: `f` is smuggled across the 'static job boundary as a
        // raw pointer. Every job is joined below before this function
        // returns, so the pointer never outlives the borrow; jobs that
        // panic still release the scope via `ScopeTicket`'s Drop. `F:
        // Sync` makes the concurrent `&F` calls sound.
        let fp = f as *const F as usize;
        for i in 0..n {
            let scope = Arc::clone(&scope);
            self.submit(move || {
                let _ticket = ScopeTicket(scope);
                unsafe { (*(fp as *const F))(i) }
            });
        }
        let mut left = scope.remaining.lock().unwrap();
        while *left > 0 {
            left = scope.done.wait(left).unwrap();
        }
        drop(left);
        // The flag is set in ScopeTicket::drop, BEFORE the final
        // decrement/notify (ordered by the scope mutex), so it cannot
        // race the wakeup; being scope-local, a panic in an unrelated
        // pool job can never fail a successful scope.
        assert!(!scope.job_panicked.load(Ordering::SeqCst), "a scoped pool job panicked");
    }

    /// Chunked scoped fan-out: split `0..n` into contiguous ranges of at
    /// most `chunk` indices and run `f(range)` for each across the pool,
    /// returning once these jobs complete. One job per chunk (not per
    /// index), so fine-grained work like centroid-tile scoring amortizes
    /// the queue round-trip. Same contract as
    /// [`ThreadPool::scope_for_each`]: `f` may borrow the caller's
    /// stack, panics are re-raised, and it must not be called from a
    /// pool worker.
    pub fn scope_for_each_chunks<F: Fn(std::ops::Range<usize>) + Sync>(
        &self,
        n: usize,
        chunk: usize,
        f: &F,
    ) {
        if n == 0 {
            return;
        }
        let chunk = chunk.max(1);
        let jobs = n.div_ceil(chunk);
        let run = |j: usize| {
            let lo = j * chunk;
            f(lo..n.min(lo + chunk));
        };
        self.scope_for_each(jobs, &run);
    }

    /// Mutable scoped fan-out: run `f(i, &mut items[i])` for every item
    /// across the pool, returning once these jobs complete. Each job
    /// receives a *disjoint* element, so `T` only needs `Send`; the
    /// engine fans per-session KV appends out through here (sessions
    /// are disjoint `&mut SessionState`s). Same contract as
    /// [`ThreadPool::scope_for_each`]: panics are re-raised, and it
    /// must not be called from a pool worker.
    pub fn scope_for_each_mut<T: Send, F: Fn(usize, &mut T) + Sync>(
        &self,
        items: &mut [T],
        f: &F,
    ) {
        let n = items.len();
        if n == 0 {
            return;
        }
        let scope = Arc::new(Scope {
            remaining: Mutex::new(n),
            done: Condvar::new(),
            job_panicked: AtomicBool::new(false),
        });
        // SAFETY: as in `scope_for_each`, `f` and the slice base are
        // smuggled across the 'static job boundary as raw addresses.
        // Every job is joined below before this function returns, so
        // neither pointer outlives its borrow; each job dereferences a
        // distinct element (`add(i)`, unique `i`), so the `&mut`s are
        // disjoint. `T: Send` moves the elements' mutable access across
        // threads; `F: Sync` makes the concurrent `&F` calls sound.
        let base = items.as_mut_ptr() as usize;
        let fp = f as *const F as usize;
        for i in 0..n {
            let scope = Arc::clone(&scope);
            self.submit(move || {
                let _ticket = ScopeTicket(scope);
                unsafe {
                    let item = &mut *(base as *mut T).add(i);
                    (*(fp as *const F))(i, item)
                }
            });
        }
        let mut left = scope.remaining.lock().unwrap();
        while *left > 0 {
            left = scope.done.wait(left).unwrap();
        }
        drop(left);
        assert!(!scope.job_panicked.load(Ordering::SeqCst), "a scoped pool job panicked");
    }
}

/// Join state of one `scope_for_each` call.
struct Scope {
    remaining: Mutex<usize>,
    done: Condvar,
    job_panicked: AtomicBool,
}

/// Releases one unit of a `scope_for_each` scope, panic or not.
struct ScopeTicket(Arc<Scope>);

impl Drop for ScopeTicket {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.job_panicked.store(true, Ordering::SeqCst);
        }
        let mut left = self.0.remaining.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.0.done.notify_all();
        }
    }
}

#[derive(Clone, Copy)]
enum Lane {
    Compute,
    Io,
}

fn worker_loop(shared: Arc<Shared>, lane: Lane) {
    loop {
        let job = {
            let (queue, available) = match lane {
                Lane::Compute => (&shared.queue, &shared.available),
                Lane::Io => (&shared.io_queue, &shared.io_available),
            };
            let mut q = queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if *shared.shutdown.lock().unwrap() {
                    break None;
                }
                q = available.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => {
                // Contain job panics: the worker survives, the panic is
                // counted, and scoped callers re-raise it. Without this
                // a panicking job would strand `in_flight` and deadlock
                // every later `wait_idle`.
                if std::panic::catch_unwind(std::panic::AssertUnwindSafe(j)).is_err() {
                    shared.panicked.fetch_add(1, Ordering::SeqCst);
                }
                if let Lane::Io = lane {
                    shared.io_in_flight.fetch_sub(1, Ordering::SeqCst);
                }
                if shared.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
                    // last job: wake any wait_idle callers (the barrier
                    // waits on the compute queue's mutex for both lanes)
                    let _guard = shared.queue.lock().unwrap();
                    shared.done.notify_all();
                }
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.wait_idle();
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        self.shared.io_available.notify_all();
        for w in self.workers.drain(..).chain(self.io_workers.drain(..)) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn wait_idle_on_empty_pool() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not deadlock
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn scoped_for_each_covers_indices() {
        let pool = ThreadPool::new(3);
        let hits = Arc::new(Mutex::new(vec![false; 64]));
        let h = Arc::clone(&hits);
        pool.scoped_for_each(
            64,
            Arc::new(move |i| {
                h.lock().unwrap()[i] = true;
            }),
        );
        assert!(hits.lock().unwrap().iter().all(|&b| b));
    }

    #[test]
    fn scope_for_each_borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let input: Vec<u64> = (0..128).collect();
        let out: Vec<Mutex<u64>> = (0..128).map(|_| Mutex::new(0)).collect();
        pool.scope_for_each(input.len(), &|i| {
            *out[i].lock().unwrap() = input[i] * 2;
        });
        for (i, o) in out.iter().enumerate() {
            assert_eq!(*o.lock().unwrap(), 2 * i as u64);
        }
    }

    #[test]
    fn scope_waits_only_for_its_own_jobs() {
        // A slow unrelated job must not block the scope's return.
        let pool = ThreadPool::new(2);
        let slow = Arc::new(AtomicU64::new(0));
        let s = Arc::clone(&slow);
        pool.submit(move || {
            std::thread::sleep(std::time::Duration::from_millis(200));
            s.store(1, Ordering::SeqCst);
        });
        let hits = Mutex::new(0usize);
        pool.scope_for_each(8, &|_| {
            *hits.lock().unwrap() += 1;
        });
        assert_eq!(*hits.lock().unwrap(), 8);
        pool.wait_idle();
        assert_eq!(slow.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scope_for_each_chunks_covers_every_index_once() {
        let pool = ThreadPool::new(3);
        for (n, chunk) in [(64usize, 16usize), (65, 16), (7, 100), (16, 1), (1, 1)] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.scope_for_each_chunks(n, chunk, &|range| {
                for i in range {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                "n={n} chunk={chunk}"
            );
        }
        // empty input is a no-op, not a hang
        pool.scope_for_each_chunks(0, 8, &|_| panic!("must not run"));
    }

    #[test]
    fn scope_for_each_mut_gives_disjoint_mutable_access() {
        let pool = ThreadPool::new(4);
        let mut items: Vec<Vec<u64>> = (0..64).map(|i| vec![i]).collect();
        pool.scope_for_each_mut(&mut items, &|i, v| {
            v.push(2 * i as u64);
        });
        for (i, v) in items.iter().enumerate() {
            assert_eq!(v, &vec![i as u64, 2 * i as u64]);
        }
        // empty input is a no-op, not a hang
        let mut none: Vec<u64> = Vec::new();
        pool.scope_for_each_mut(&mut none, &|_, _| {});
    }

    #[test]
    #[should_panic(expected = "scoped pool job panicked")]
    fn scope_for_each_mut_reraises_job_panics() {
        let pool = ThreadPool::new(2);
        let mut items = vec![0u64; 4];
        pool.scope_for_each_mut(&mut items, &|i, _| {
            if i == 1 {
                panic!("boom");
            }
        });
    }

    #[test]
    #[should_panic(expected = "scoped pool job panicked")]
    fn scope_reraises_job_panics() {
        let pool = ThreadPool::new(2);
        pool.scope_for_each(4, &|i| {
            if i == 2 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_job_panic() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("contained"));
        pool.wait_idle();
        assert_eq!(pool.panicked(), 1);
        // pool still functional afterwards
        let c = Arc::new(AtomicU64::new(0));
        let c2 = Arc::clone(&c);
        pool.submit(move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(c.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn io_lane_runs_jobs_and_wait_idle_covers_both_lanes() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c2 = Arc::clone(&c);
            pool.submit_io(move || {
                c2.fetch_add(1, Ordering::SeqCst);
            });
            let c2 = Arc::clone(&c);
            pool.submit(move || {
                c2.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(c.load(Ordering::SeqCst), 200);
        assert_eq!(pool.io_pending(), 0);
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn slow_io_jobs_cannot_starve_compute_scopes() {
        // Saturate the single I/O worker with slow jobs, then run a
        // compute fan-out: it must complete while the I/O backlog is
        // still in flight — the lanes share no workers.
        let pool = ThreadPool::with_io_threads(2, 1);
        let io_done = Arc::new(AtomicU64::new(0));
        for _ in 0..4 {
            let d = Arc::clone(&io_done);
            pool.submit_io(move || {
                std::thread::sleep(std::time::Duration::from_millis(50));
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        let hits = Mutex::new(0usize);
        pool.scope_for_each(16, &|_| {
            *hits.lock().unwrap() += 1;
        });
        assert_eq!(*hits.lock().unwrap(), 16);
        assert!(
            io_done.load(Ordering::SeqCst) < 4,
            "compute scope should finish before the slow I/O backlog drains"
        );
        pool.wait_idle();
        assert_eq!(io_done.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn compute_backlog_cannot_starve_io_lane() {
        // The reverse direction: a pile of slow compute jobs must not
        // delay an I/O job behind them.
        let pool = ThreadPool::with_io_threads(1, 1);
        for _ in 0..4 {
            pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(50)));
        }
        let (tx, rx) = std::sync::mpsc::channel();
        pool.submit_io(move || {
            tx.send(()).unwrap();
        });
        rx.recv_timeout(std::time::Duration::from_millis(100))
            .expect("I/O job stuck behind the compute backlog");
        pool.wait_idle();
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }
}
