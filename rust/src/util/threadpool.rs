//! Thread-pool substrate (no `tokio`/`rayon` available offline).
//!
//! A fixed pool of workers consuming boxed jobs from a shared queue.
//! Used by the wave buffer for asynchronous cache updates (paper §4.3:
//! "cache updates are decoupled from cache access ... performed
//! asynchronously by the CPU, in parallel with the data copy and
//! attention computation") and by experiment harnesses for parallel
//! trials.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    /// jobs submitted but not yet finished
    in_flight: AtomicUsize,
    done: Condvar,
    shutdown: Mutex<bool>,
}

/// Fixed-size worker pool with a `wait_idle` barrier.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n_threads: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let workers = (0..n_threads.max(1))
            .map(|_| {
                let s = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(s))
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Enqueue a job for asynchronous execution.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(f));
        }
        self.shared.available.notify_one();
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.queue.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    pub fn pending(&self) -> usize {
        self.shared.in_flight.load(Ordering::SeqCst)
    }

    pub fn n_threads(&self) -> usize {
        self.workers.len()
    }

    /// Run a closure over every index in `0..n` across the pool, blocking
    /// until all are done (scoped-parallel map for experiment harnesses).
    pub fn scoped_for_each<F: Fn(usize) + Send + Sync + 'static>(&self, n: usize, f: Arc<F>) {
        for i in 0..n {
            let f = Arc::clone(&f);
            self.submit(move || f(i));
        }
        self.wait_idle();
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if *shared.shutdown.lock().unwrap() {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => {
                j();
                if shared.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
                    // last job: wake any wait_idle callers
                    let _guard = shared.queue.lock().unwrap();
                    shared.done.notify_all();
                }
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.wait_idle();
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn wait_idle_on_empty_pool() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not deadlock
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn scoped_for_each_covers_indices() {
        let pool = ThreadPool::new(3);
        let hits = Arc::new(Mutex::new(vec![false; 64]));
        let h = Arc::clone(&hits);
        pool.scoped_for_each(
            64,
            Arc::new(move |i| {
                h.lock().unwrap()[i] = true;
            }),
        );
        assert!(hits.lock().unwrap().iter().all(|&b| b));
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }
}
