//! Serving metrics: named counters, point-in-time gauges and latency
//! histograms with percentile summaries, shared across coordinator /
//! engine / benches.

use crate::util::stats::{LogHistogram, Sample};
use std::collections::HashMap;
use std::sync::Mutex;

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<HashMap<String, u64>>,
    gauges: Mutex<HashMap<String, u64>>,
    samples: Mutex<HashMap<String, Sample>>,
    /// Streaming histograms for unbounded online series (TTFT/TPOT):
    /// fixed memory per series, percentile queries without stored
    /// samples — `samples` above is for bounded bench-scale data.
    hists: Mutex<HashMap<String, LogHistogram>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Set a point-in-time gauge (e.g. arena occupancy after an event).
    pub fn set_gauge(&self, name: &str, v: u64) {
        self.gauges.lock().unwrap().insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Raise a gauge to `v` only if `v` exceeds its current value —
    /// high-water-mark tracking (e.g. peak arena occupancy, the number
    /// the capacity invariant is asserted against).
    pub fn set_gauge_max(&self, name: &str, v: u64) {
        let mut g = self.gauges.lock().unwrap();
        let e = g.entry(name.to_string()).or_insert(0);
        if v > *e {
            *e = v;
        }
    }

    /// Set gauge `name` to the integer percentage `100 * num / den`
    /// (0 when `den` is zero — an empty ratio reports no activity, so a
    /// run that never exercised the rate cannot read as a perfect one).
    /// Used for rates like the spill prefetch-overlap ratio (staged
    /// promotions / promotions).
    pub fn set_ratio_gauge(&self, name: &str, num: u64, den: u64) {
        let v = if den == 0 {
            0
        } else {
            (100.0 * num as f64 / den as f64).round() as u64
        };
        self.set_gauge(name, v);
    }

    /// Snapshot of all gauges, sorted by name.
    pub fn gauges_snapshot(&self) -> Vec<(String, u64)> {
        let mut v: Vec<(String, u64)> =
            self.gauges.lock().unwrap().iter().map(|(k, g)| (k.clone(), *g)).collect();
        v.sort();
        v
    }

    /// Record one observation (e.g. a latency in seconds).
    pub fn observe(&self, name: &str, v: f64) {
        self.samples.lock().unwrap().entry(name.to_string()).or_default().add(v);
    }

    pub fn percentile(&self, name: &str, p: f64) -> f64 {
        self.samples
            .lock()
            .unwrap()
            .get_mut(name)
            .map(|s| s.percentile(p))
            .unwrap_or(f64::NAN)
    }

    pub fn mean(&self, name: &str) -> f64 {
        self.samples.lock().unwrap().get(name).map(|s| s.mean()).unwrap_or(f64::NAN)
    }

    pub fn count(&self, name: &str) -> usize {
        self.samples.lock().unwrap().get(name).map(|s| s.len()).unwrap_or(0)
    }

    /// One-line human summary of a latency series.
    pub fn summary(&self, name: &str) -> String {
        let mut g = self.samples.lock().unwrap();
        match g.get_mut(name) {
            Some(s) if !s.is_empty() => format!(
                "{name}: n={} mean={:.3}ms p50={:.3}ms p99={:.3}ms",
                s.len(),
                s.mean() * 1e3,
                s.percentile(50.0) * 1e3,
                s.percentile(99.0) * 1e3,
            ),
            _ => format!("{name}: (no samples)"),
        }
    }

    /// Record into a streaming log-bucket histogram (serving-latency
    /// geometry, 1µs..1000s). Unlike [`Metrics::observe`] this stores
    /// no samples: memory stays fixed no matter how long the serving
    /// run is, at ~6% relative percentile error.
    pub fn observe_hist(&self, name: &str, v: f64) {
        self.hists
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(LogHistogram::latency_s)
            .observe(v);
    }

    /// Percentile from a streaming histogram (`NaN` when absent/empty).
    pub fn hist_percentile(&self, name: &str, p: f64) -> f64 {
        self.hists.lock().unwrap().get(name).map(|h| h.percentile(p)).unwrap_or(f64::NAN)
    }

    pub fn hist_count(&self, name: &str) -> u64 {
        self.hists.lock().unwrap().get(name).map(|h| h.count()).unwrap_or(0)
    }

    /// Clone of a streaming histogram for cross-replica merging.
    pub fn hist_snapshot(&self, name: &str) -> Option<LogHistogram> {
        self.hists.lock().unwrap().get(name).cloned()
    }

    /// One-line p50/p95/p99 summary of a streaming histogram.
    pub fn hist_summary(&self, name: &str) -> String {
        let g = self.hists.lock().unwrap();
        match g.get(name) {
            Some(h) if !h.is_empty() => format!(
                "{name}: n={} p50={:.3}ms p95={:.3}ms p99={:.3}ms",
                h.count(),
                h.percentile(50.0) * 1e3,
                h.percentile(95.0) * 1e3,
                h.percentile(99.0) * 1e3,
            ),
            _ => format!("{name}: (no samples)"),
        }
    }

    /// Snapshot of all counters, sorted by name.
    pub fn counters_snapshot(&self) -> Vec<(String, u64)> {
        let mut v: Vec<(String, u64)> =
            self.counters.lock().unwrap().iter().map(|(k, c)| (k.clone(), *c)).collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("req", 1);
        m.inc("req", 2);
        assert_eq!(m.counter("req"), 3);
        assert_eq!(m.counter("absent"), 0);
    }

    #[test]
    fn percentiles_from_observations() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("lat", i as f64);
        }
        assert_eq!(m.count("lat"), 100);
        assert!((m.percentile("lat", 50.0) - 50.0).abs() <= 1.0);
        assert!(m.percentile("lat", 99.0) >= 98.0);
        assert!((m.mean("lat") - 50.5).abs() < 1e-9);
    }

    #[test]
    fn summary_handles_missing_series() {
        let m = Metrics::new();
        assert!(m.summary("nope").contains("no samples"));
    }

    #[test]
    fn gauges_overwrite_not_accumulate() {
        let m = Metrics::new();
        m.set_gauge("arena_live_blocks", 7);
        m.set_gauge("arena_live_blocks", 3);
        assert_eq!(m.gauge("arena_live_blocks"), 3);
        assert_eq!(m.gauge("absent"), 0);
        assert_eq!(m.gauges_snapshot(), vec![("arena_live_blocks".to_string(), 3)]);
    }

    #[test]
    fn gauge_max_tracks_high_water_mark() {
        let m = Metrics::new();
        m.set_gauge_max("peak", 5);
        m.set_gauge_max("peak", 3);
        assert_eq!(m.gauge("peak"), 5);
        m.set_gauge_max("peak", 9);
        assert_eq!(m.gauge("peak"), 9);
    }

    #[test]
    fn ratio_gauge_is_integer_percent() {
        let m = Metrics::new();
        m.set_ratio_gauge("overlap", 3, 4);
        assert_eq!(m.gauge("overlap"), 75);
        m.set_ratio_gauge("overlap", 0, 0);
        assert_eq!(m.gauge("overlap"), 0, "empty ratio reports no activity");
        m.set_ratio_gauge("overlap", 1, 3);
        assert_eq!(m.gauge("overlap"), 33);
    }

    #[test]
    fn streaming_hist_percentiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_hist("ttft_s", i as f64 * 1e-3);
        }
        assert_eq!(m.hist_count("ttft_s"), 100);
        let p50 = m.hist_percentile("ttft_s", 50.0);
        assert!(p50 > 0.045 && p50 < 0.056, "p50 within a bucket of 50ms: {p50}");
        assert!(m.hist_percentile("absent", 50.0).is_nan());
        assert_eq!(m.hist_count("absent"), 0);
        let s = m.hist_summary("ttft_s");
        assert!(s.contains("n=100") && s.contains("p99"), "{s}");
        assert!(m.hist_summary("absent").contains("no samples"));
        // snapshots merge across registries (cluster aggregation path)
        let m2 = Metrics::new();
        m2.observe_hist("ttft_s", 0.2);
        let mut merged = m.hist_snapshot("ttft_s").unwrap();
        merged.merge(&m2.hist_snapshot("ttft_s").unwrap());
        assert_eq!(merged.count(), 101);
        assert_eq!(merged.max(), 0.2);
    }

    #[test]
    fn snapshot_sorted() {
        let m = Metrics::new();
        m.inc("b", 1);
        m.inc("a", 1);
        let snap = m.counters_snapshot();
        assert_eq!(snap[0].0, "a");
    }
}
