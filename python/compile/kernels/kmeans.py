"""L1/L2: segmented spherical k-means for wave-index construction.

Paper §4.2 "segmented clustering": the input sequence is divided into
segments and spherical k-means runs *within* each segment independently
(the paper implements this as a Triton kernel parallel over heads and
segments). Here the per-iteration nearest-centroid assignment is a Pallas
kernel (the O(S*C*d) hot loop) and the centroid update is jnp segment-sums,
all lowered into the same HLO artifact.

Two details that matter for correctness of the estimation bound (Eq. 3):

  * Clustering *geometry* uses centered (all-but-the-top / MagicPIG-style
    mean subtraction) and L2-normalized keys, which is what makes
    inner-product clustering align with attention importance for
    out-of-distribution queries.
  * The *meta-index centroid* returned to the engine is the raw arithmetic
    mean of the member keys (NOT the normalized cluster direction), because
    Jensen's inequality `exp(q.C_i) <= mean_j exp(q.K_j)` only holds for
    the true mean.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(k_ref, c_ref, a_ref, *, block_s: int, n_points: int):
    """One grid step = one (head,) row; loops over point blocks.

    k_ref [1, S, d] centered+normalized keys; c_ref [1, C, d] centroids;
    a_ref [1, S] int32 nearest-centroid ids.
    """
    cent = c_ref[0]  # (C, d)

    def step(i, _):
        k = pl.load(k_ref, (0, pl.ds(i * block_s, block_s), slice(None)))
        sims = jnp.dot(k, cent.T)  # (block_s, C)
        idx = jnp.argmax(sims, axis=-1).astype(jnp.int32)
        pl.store(a_ref, (0, pl.ds(i * block_s, block_s)), idx)
        return 0

    jax.lax.fori_loop(0, n_points // block_s, step, 0)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def kmeans_assign(keys, cent, *, block_s: int = 256, interpret: bool = True):
    """Pallas nearest-centroid assignment: keys [H,S,d], cent [H,C,d] -> [H,S]."""
    h, s, d = keys.shape
    c = cent.shape[1]
    pad = (-s) % block_s
    if pad:
        keys = jnp.pad(keys, ((0, 0), (0, pad), (0, 0)))
    sp = keys.shape[1]
    kernel = functools.partial(_assign_kernel, block_s=block_s, n_points=sp)
    out = pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, sp, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, c, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, sp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sp), jnp.int32),
        interpret=interpret,
    )(keys, cent)
    return out[:, :s]


def _center_normalize(keys):
    """Mean-center per head then L2-normalize (clustering geometry)."""
    mu = jnp.mean(keys, axis=1, keepdims=True)
    kc = keys - mu
    norm = jnp.maximum(jnp.linalg.norm(kc, axis=-1, keepdims=True), 1e-12)
    return kc / norm


def _update_centroids(kcn, assign, n_clusters):
    """Segment-sum centroid update; empty clusters keep their old direction
    encoded as zeros (they are masked out downstream via size == 0)."""
    onehot = jax.nn.one_hot(assign, n_clusters, dtype=jnp.float32)  # [H,S,C]
    counts = jnp.sum(onehot, axis=1)  # [H,C]
    sums = jnp.einsum("hsc,hsd->hcd", onehot, kcn)
    cent = sums / jnp.maximum(counts[..., None], 1.0)
    norm = jnp.maximum(jnp.linalg.norm(cent, axis=-1, keepdims=True), 1e-12)
    return cent / norm, counts


@functools.partial(
    jax.jit, static_argnames=("n_clusters", "n_iters", "interpret", "block_s")
)
def segmented_kmeans(
    keys,
    values,
    *,
    n_clusters: int,
    n_iters: int = 10,
    interpret: bool = True,
    block_s: int = 256,
):
    """Spherical k-means over one segment, per KV head.

    keys/values [H, S, d] (post-RoPE keys, matching the paper's finding that
    RoPE is the source of the spatial locality segmentation exploits).

    Returns (meta_cent, vsum, counts, assign):
      meta_cent [H, C, d]  raw-mean centroids for the meta index
      vsum      [H, C, d]  summed value vectors per cluster
      counts    [H, C]     cluster sizes (float32)
      assign    [H, S]     cluster id per token (int32)
    """
    h, s, d = keys.shape
    kcn = _center_normalize(keys)

    # Strided init: spreads initial centroids across the segment, which under
    # RoPE locality is close to k-means++ quality at zero cost.
    stride = max(s // n_clusters, 1)
    cent0 = kcn[:, :: stride, :][:, :n_clusters, :]
    if cent0.shape[1] < n_clusters:
        reps = -(-n_clusters // cent0.shape[1])
        cent0 = jnp.tile(cent0, (1, reps, 1))[:, :n_clusters, :]

    def body(_, cent):
        assign = kmeans_assign(kcn, cent, block_s=block_s, interpret=interpret)
        cent, _ = _update_centroids(kcn, assign, n_clusters)
        return cent

    cent = jax.lax.fori_loop(0, n_iters, body, cent0)
    assign = kmeans_assign(kcn, cent, block_s=block_s, interpret=interpret)

    onehot = jax.nn.one_hot(assign, n_clusters, dtype=jnp.float32)
    counts = jnp.sum(onehot, axis=1)
    ksum = jnp.einsum("hsc,hsd->hcd", onehot, keys)
    vsum = jnp.einsum("hsc,hsd->hcd", onehot, values)
    meta_cent = ksum / jnp.maximum(counts[..., None], 1.0)
    return meta_cent, vsum, counts, assign
