"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every kernel is validated against
these dense reference implementations by pytest (+hypothesis sweeps over
shapes) at build time.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def ref_wave_attention(q, kx, vx, kmask, cent, vsum, csize, emask):
    """Dense tripartite attention (paper Eq. 2-4), no blocking.

    Same shapes as `wave_attention.wave_attention`. Computes

        D   = sum_valid exp(q.k) + sum_est s_j * exp(q.C_j)
        out = ( sum_valid exp(q.k) v  +  sum_est exp(q.C_j) VS_j ) / D
    """
    d = q.shape[-1]
    qs = q * (1.0 / jnp.sqrt(jnp.float32(d)))

    # exact part: [B, KVH, G, Ne]
    se = jnp.einsum("bhgd,bhnd->bhgn", qs, kx)
    se = jnp.where(kmask[:, :, None, :] > 0.5, se, NEG_INF)
    # estimation part: [B, KVH, G, M]
    sc = jnp.einsum("bhgd,bhmd->bhgm", qs, cent)
    sc = jnp.where(emask[:, :, None, :] > 0.5, sc, NEG_INF)

    m = jnp.maximum(jnp.max(se, axis=-1), jnp.max(sc, axis=-1))  # [B,KVH,G]
    pe = jnp.exp(se - m[..., None]) * (kmask[:, :, None, :] > 0.5)
    pc = jnp.exp(sc - m[..., None]) * (emask[:, :, None, :] > 0.5)

    denom = jnp.sum(pe, axis=-1) + jnp.sum(pc * csize[:, :, None, :], axis=-1)
    denom = jnp.maximum(denom, 1e-30)
    num = jnp.einsum("bhgn,bhnd->bhgd", pe, vx) + jnp.einsum(
        "bhgm,bhmd->bhgd", pc, vsum
    )
    return num / denom[..., None]


def ref_full_attention(q, k, v, mask):
    """Standard masked softmax attention.

    q [B, KVH, G, d]; k/v [B, KVH, T, d]; mask [B, KVH, T]
    """
    d = q.shape[-1]
    s = jnp.einsum("bhgd,bhtd->bhgt", q, k) / jnp.sqrt(jnp.float32(d))
    s = jnp.where(mask[:, :, None, :] > 0.5, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * (mask[:, :, None, :] > 0.5)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhgt,bhtd->bhgd", p / denom, v)


def ref_kmeans_assign(keys, cent):
    """Nearest-centroid assignment by inner product.

    keys [KVH, S, d]; cent [KVH, C, d] -> assign [KVH, S] int32
    """
    sims = jnp.einsum("hsd,hcd->hsc", keys, cent)
    return jnp.argmax(sims, axis=-1).astype(jnp.int32)


def ref_attention_weights(q, k):
    """Full softmax attention weights (for sparsity analysis figures).

    q [G, d], k [T, d] -> [G, T]
    """
    d = q.shape[-1]
    s = q @ k.T / jnp.sqrt(jnp.float32(d))
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    return p / jnp.sum(p, axis=-1, keepdims=True)
