"""L1: tripartite weighted flash-attention Pallas kernel.

This is the compute hot-spot of RetroInfer (paper §4.2 + §4.6): a single
online-softmax pass that merges

  * exact attention over the *steady zone* and *retrieval zone* tokens
    (the execution buffer assembled by the wave buffer), and
  * accuracy-bounded *estimation zone* attention, where each non-retrieved
    cluster contributes through its centroid `C_j`, cluster size `s_j` and
    summed value vector `VS_j` (Eq. 2-4 of the paper):

        denominator += s_j * exp(q . C_j / sqrt(d))
        numerator   +=       exp(q . C_j / sqrt(d)) * VS_j

  which is exactly the "weighted attention" the paper implements by
  modifying FlashAttention.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA threadblock
tiling of the paper becomes a sequential key-block loop whose tiles are
pulled into VMEM-sized blocks (`block_k` keys x d). GQA is expressed by
giving each grid step one KV head and the whole group of query heads
(`G = q_heads // kv_heads`), so the MXU sees (G x d) @ (d x block_k)
matmuls. The kernel MUST be run with ``interpret=True`` on this image:
real-TPU lowering emits a Mosaic custom-call that the CPU PJRT plugin
cannot execute.

Shapes (all float32):
  q      [B, KVH, G, d]   queries, grouped per KV head, PRE-SCALED by 1/sqrt(d)
  kx     [B, KVH, Ne, d]  exact keys   (steady zone + execution buffer)
  vx     [B, KVH, Ne, d]  exact values
  kmask  [B, KVH, Ne]     1.0 = valid exact token, 0.0 = padding
  cent   [B, KVH, M, d]   cluster centroids (raw mean of member keys)
  vsum   [B, KVH, M, d]   per-cluster summed value vectors
  csize  [B, KVH, M]      per-cluster token counts (float)
  emask  [B, KVH, M]      1.0 = cluster is in the estimation zone
  -> out [B, KVH, G, d]
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _wave_attention_kernel(
    q_ref,
    k_ref,
    v_ref,
    kmask_ref,
    c_ref,
    vs_ref,
    s_ref,
    emask_ref,
    o_ref,
    *,
    block_k: int,
    n_exact: int,
    n_clusters: int,
):
    """One grid step = one (batch, kv_head) pair; loops over key blocks."""
    g, d = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0]  # (G, d), already scaled by 1/sqrt(d)

    m0 = jnp.full((g,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((g,), dtype=jnp.float32)
    a0 = jnp.zeros((g, d), dtype=jnp.float32)

    def exact_step(i, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, 0, pl.ds(i * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, 0, pl.ds(i * block_k, block_k), slice(None)))
        msk = pl.load(kmask_ref, (0, 0, pl.ds(i * block_k, block_k)))
        s = jnp.dot(q, k.T)  # (G, block_k)
        s = jnp.where(msk[None, :] > 0.5, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        # exp of masked entries is forced to zero via the mask product so a
        # fully-masked block cannot poison the running sum (exp(-inf - -inf)
        # would otherwise be 1).
        p = jnp.exp(s - m_new[:, None]) * msk[None, :]
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l, acc

    def estimate_step(i, carry):
        m, l, acc = carry
        c = pl.load(c_ref, (0, 0, pl.ds(i * block_k, block_k), slice(None)))
        vs = pl.load(vs_ref, (0, 0, pl.ds(i * block_k, block_k), slice(None)))
        sz = pl.load(s_ref, (0, 0, pl.ds(i * block_k, block_k)))
        msk = pl.load(emask_ref, (0, 0, pl.ds(i * block_k, block_k)))
        s = jnp.dot(q, c.T)  # (G, block_k) centroid scores
        s = jnp.where(msk[None, :] > 0.5, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None]) * msk[None, :]
        alpha = jnp.exp(m - m_new)
        # Weighted attention: cluster size scales the softmax denominator,
        # the summed value vector enters the numerator unscaled (Eq. 4).
        l = l * alpha + jnp.sum(p * sz[None, :], axis=1)
        acc = acc * alpha[:, None] + jnp.dot(p, vs)
        return m_new, l, acc

    n_kb = n_exact // block_k
    n_cb = n_clusters // block_k
    carry = jax.lax.fori_loop(0, n_kb, exact_step, (m0, l0, a0))
    m, l, acc = jax.lax.fori_loop(0, n_cb, estimate_step, carry)
    l = jnp.maximum(l, 1e-30)
    o_ref[0, 0] = acc / l[:, None]


def _pad_axis(x, axis, to_multiple):
    n = x.shape[axis]
    pad = (-n) % to_multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def wave_attention(
    q, kx, vx, kmask, cent, vsum, csize, emask, *, block_k: int = 128, interpret: bool = True
):
    """Tripartite attention: exact (steady+retrieval) merged with estimation.

    `q` is the raw query [B, KVH, G, d]; scaling by 1/sqrt(d) happens here so
    callers pass model-space tensors. Inputs are padded to `block_k`
    multiples; padding is masked out.
    """
    b, kvh, g, d = q.shape
    qs = q * (1.0 / jnp.sqrt(jnp.float32(d)))

    kx = _pad_axis(kx, 2, block_k)
    vx = _pad_axis(vx, 2, block_k)
    kmask = _pad_axis(kmask, 2, block_k)
    cent = _pad_axis(cent, 2, block_k)
    vsum = _pad_axis(vsum, 2, block_k)
    csize = _pad_axis(csize, 2, block_k)
    emask = _pad_axis(emask, 2, block_k)
    n_exact = kx.shape[2]
    n_clusters = cent.shape[2]

    kernel = functools.partial(
        _wave_attention_kernel,
        block_k=block_k,
        n_exact=n_exact,
        n_clusters=n_clusters,
    )

    def spec(*trailing):
        return pl.BlockSpec((1, 1) + trailing, lambda i, j: (i, j) + (0,) * len(trailing))

    return pl.pallas_call(
        kernel,
        grid=(b, kvh),
        in_specs=[
            spec(g, d),
            spec(n_exact, d),
            spec(n_exact, d),
            spec(n_exact),
            spec(n_clusters, d),
            spec(n_clusters, d),
            spec(n_clusters),
            spec(n_clusters),
        ],
        out_specs=spec(g, d),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), jnp.float32),
        interpret=interpret,
    )(qs, kx, vx, kmask, cent, vsum, csize, emask)
