"""AOT pipeline: lower every L2 entry point to HLO *text* artifacts.

Python runs ONCE (`make artifacts`); the Rust binary is self-contained
afterwards. The interchange format is HLO text, NOT `.serialize()`:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
image's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Outputs in artifacts/:
  <name>.hlo.txt      one per (entry point, shape bucket)
  weights.bin         TinyLM weights, flat f32 in `weight_specs` order
  manifest.json       machine-readable description consumed by the Rust
                      runtime: model config, zone defaults, weight layout,
                      executable signatures (param/output names + shapes)
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.kmeans import segmented_kmeans

CFG = M.CFG

# Live-path shape buckets (DESIGN.md §5). Batches handled by the dynamic
# batcher; contexts by prefill/attention buckets.
BATCH_BUCKETS = (1, 2, 4, 8)
PREFILL_T = (2048, 4096, 8192)
ATTN_FULL_T = 8192          # full-attention cache capacity (masked by length)
WAVE_NE = 1152              # steady zone + execution buffer, padded to 128
WAVE_M = 512                # meta-index capacity (8K ctx / 16 tokens per cluster)
STEADY_SINK = 4
STEADY_LOCAL = 64
KMEANS_SEGMENTS = ((8192, 512), (1024, 64))  # (segment, clusters): build, update
PREFILL_CHUNK = 512


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _w(name):
    """ShapeDtypeStruct for a named weight."""
    shapes = dict(M.weight_specs())
    return _spec(shapes[name])


def entry_points():
    """Yield (name, fn, arg_specs, param_names, output_names)."""
    L, D, V = CFG.n_layers, CFG.d_model, CFG.vocab
    KVH, G, dh = CFG.kv_heads, CFG.group, CFG.d_head
    i32 = jnp.int32

    eps = []

    for b in BATCH_BUCKETS:
        eps.append((
            f"embed_b{b}",
            lambda tok_emb, tokens: (M.embed_step(tok_emb, tokens),),
            [_w("tok_emb"), _spec((b,), i32)],
            ["tok_emb", "tokens"], ["hidden"],
        ))
        # per-LAYER weight params: 4x smaller host->device copies per call
        eps.append((
            f"qkv_b{b}",
            lambda ln1_l, wq_l, wk_l, wv_l, hidden, pos: M.qkv_step_l(
                ln1_l, wq_l, wk_l, wv_l, hidden, pos
            ),
            [_spec((D,)), _spec((D, CFG.q_dim)), _spec((D, CFG.kv_dim)),
             _spec((D, CFG.kv_dim)), _spec((b, D)), _spec((b,), i32)],
            ["ln1_l", "wq_l", "wk_l", "wv_l", "hidden", "pos"],
            ["q", "k", "v"],
        ))
        eps.append((
            f"mlp_b{b}",
            lambda wo_l, ln2_l, w1_l, w2_l, hidden, ctx: (
                M.mlp_step_l(wo_l, ln2_l, w1_l, w2_l, hidden, ctx),
            ),
            [_spec((CFG.q_dim, D)), _spec((D,)), _spec((D, CFG.ffn)),
             _spec((CFG.ffn, D)), _spec((b, D)), _spec((b, CFG.q_dim))],
            ["wo_l", "ln2_l", "w1_l", "w2_l", "hidden", "ctx"],
            ["hidden_out"],
        ))
        eps.append((
            f"logits_b{b}",
            lambda lnf, unemb, hidden: (M.logits_step(lnf, unemb, hidden),),
            [_w("lnf"), _w("unemb"), _spec((b, D))],
            ["lnf", "unemb", "hidden"], ["logits"],
        ))
        eps.append((
            f"attn_full_b{b}_t{ATTN_FULL_T}",
            lambda q, kc, vc, length: (M.attn_full_step(q, kc, vc, length),),
            [_spec((b, KVH, G, dh)), _spec((b, KVH, ATTN_FULL_T, dh)),
             _spec((b, KVH, ATTN_FULL_T, dh)), _spec((b,), i32)],
            ["q", "k_cache", "v_cache", "length"], ["ctx"],
        ))
        eps.append((
            f"attn_wave_b{b}",
            lambda q, kx, vx, kmask, cent, vsum, csize, emask: (
                M.attn_wave_step(q, kx, vx, kmask, cent, vsum, csize, emask),
            ),
            [_spec((b, KVH, G, dh)),
             _spec((b, KVH, WAVE_NE, dh)), _spec((b, KVH, WAVE_NE, dh)),
             _spec((b, KVH, WAVE_NE)),
             _spec((b, KVH, WAVE_M, dh)), _spec((b, KVH, WAVE_M, dh)),
             _spec((b, KVH, WAVE_M)), _spec((b, KVH, WAVE_M))],
            ["q", "kx", "vx", "kmask", "cent", "vsum", "csize", "emask"],
            ["ctx"],
        ))

    for t in PREFILL_T:
        eps.append((
            f"prefill_b1_t{t}",
            lambda weights_list, tokens: M.prefill(
                dict(zip(M.WEIGHT_NAMES, weights_list)), tokens, chunk=PREFILL_CHUNK
            ),
            [[_spec(s) for _, s in M.weight_specs()], _spec((1, t), i32)],
            M.WEIGHT_NAMES + ["tokens"],
            ["k_cache", "v_cache", "logits_last"],
        ))

    for seg, clusters in KMEANS_SEGMENTS:
        eps.append((
            f"kmeans_s{seg}_c{clusters}",
            (lambda c: lambda keys, values: segmented_kmeans(
                keys, values, n_clusters=c, n_iters=10
            ))(clusters),
            [_spec((KVH, seg, dh)), _spec((KVH, seg, dh))],
            ["keys", "values"],
            ["centroids", "vsum", "counts", "assign"],
        ))

    eps.append((
        "smoke",
        lambda x, y: (jnp.matmul(x, y) + 2.0,),
        [_spec((2, 2)), _spec((2, 2))],
        ["x", "y"], ["out"],
    ))
    return eps


def _flat_specs(arg_specs):
    flat = []
    for s in arg_specs:
        if isinstance(s, list):
            flat.extend(s)
        else:
            flat.append(s)
    return flat


def _dtype_name(dt):
    return {"float32": "f32", "int32": "i32"}[np.dtype(dt).name]


def lower_all(out_dir: str, only=None, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    exe_manifest = []
    for name, fn, arg_specs, param_names, output_names in entry_points():
        flat = _flat_specs(arg_specs)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        if only is None or name in only:
            lowered = jax.jit(fn).lower(*arg_specs)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            if verbose:
                print(f"  {name}: {len(text)} chars -> {path}", file=sys.stderr)
        exe_manifest.append({
            "name": name,
            "file": f"{name}.hlo.txt",
            "params": [
                {"name": pn, "dtype": _dtype_name(s.dtype), "shape": list(s.shape)}
                for pn, s in zip(param_names, flat)
            ],
            "outputs": output_names,
        })
    return exe_manifest


def write_weights(out_dir: str, seed: int = 7):
    w = M.init_weights(seed)
    manifest = []
    offset = 0
    blobs = []
    for name, shape in M.weight_specs():
        arr = np.asarray(w[name], dtype=np.float32)
        assert tuple(arr.shape) == tuple(shape)
        manifest.append({
            "name": name, "shape": list(shape),
            "offset": offset, "elements": int(arr.size),
        })
        blobs.append(arr.tobytes())
        offset += arr.size * 4
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for b in blobs:
            f.write(b)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="lower only the named entry points (manifest still lists all)")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    exes = lower_all(args.out_dir, only=args.only)
    weights = write_weights(args.out_dir, args.seed)

    manifest = {
        "model": {
            "name": "tinylm",
            "vocab": CFG.vocab, "d_model": CFG.d_model,
            "n_layers": CFG.n_layers, "q_heads": CFG.q_heads,
            "kv_heads": CFG.kv_heads, "d_head": CFG.d_head,
            "ffn": CFG.ffn, "rope_theta": CFG.rope_theta,
            "weights_file": "weights.bin", "weights_seed": args.seed,
        },
        "buckets": {
            "batch": list(BATCH_BUCKETS),
            "prefill_t": list(PREFILL_T),
            "attn_full_t": ATTN_FULL_T,
            "wave_ne": WAVE_NE,
            "wave_m": WAVE_M,
            "prefill_chunk": PREFILL_CHUNK,
        },
        "zones": {
            "steady_sink": STEADY_SINK,
            "steady_local": STEADY_LOCAL,
            "tokens_per_cluster": 16,
            "retrieval_frac": 0.018,
            "estimation_frac": 0.232,
            "build_segment": KMEANS_SEGMENTS[0][0],
            "update_segment": KMEANS_SEGMENTS[1][0],
            "kmeans_iters": 10,
        },
        "weights": weights,
        "executables": exes,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(exes)} executables + weights + manifest to {args.out_dir}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
