"""L2: TinyLM — a small GQA transformer whose decode step calls the L1 kernel.

This is the live-path model of the reproduction (DESIGN.md §1): a 4-layer
GQA transformer with deterministic synthetic weights, exercised end-to-end
through PJRT from the Rust coordinator. Paper-scale models (Llama3-8B etc.)
are represented by cost configs consumed by the Rust `memsim` — attention
*accuracy* behaviour is exercised here on real KV geometry, throughput at
paper scale is exercised by the simulator on real block traces.

The model is deliberately factored into per-layer entry points
(qkv -> attention -> mlp) because the wave index lives between them: the
Rust coordinator must see `q` to run centroid selection and assemble the
execution buffer before the attention call — exactly the GPU/CPU interplay
of the paper's Figure 5.

All entry points are pure functions of (weights..., activations...) so that
`aot.py` can lower them once to HLO text with static shape buckets.
"""

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.wave_attention import wave_attention
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class TinyLMConfig:
    vocab: int = 256
    d_model: int = 256
    n_layers: int = 4
    q_heads: int = 8
    kv_heads: int = 2
    d_head: int = 32
    ffn: int = 512
    rope_theta: float = 10000.0
    eps: float = 1e-5

    @property
    def group(self) -> int:
        return self.q_heads // self.kv_heads

    @property
    def q_dim(self) -> int:
        return self.q_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.d_head


CFG = TinyLMConfig()


def weight_specs(cfg: TinyLMConfig = CFG) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical (name, shape) list; defines the weights.bin layout."""
    L, D, F, V = cfg.n_layers, cfg.d_model, cfg.ffn, cfg.vocab
    return [
        ("tok_emb", (V, D)),
        ("ln1", (L, D)),
        ("wq", (L, D, cfg.q_dim)),
        ("wk", (L, D, cfg.kv_dim)),
        ("wv", (L, D, cfg.kv_dim)),
        ("wo", (L, cfg.q_dim, D)),
        ("ln2", (L, D)),
        ("w1", (L, D, F)),
        ("w2", (L, F, D)),
        ("lnf", (D,)),
        ("unemb", (D, V)),
    ]


#: q/k projections are sharpened at init so that TinyLM exhibits the
#: concentrated attention of *trained* LLMs (the phenomenon RetroInfer
#: exploits): with sharpen=2 the top-100-of-1024 attention mass is ~99%
#: and top-16 ~91%, matching the ~90% sparsity the paper cites (§2.3).
#: Plain 1/sqrt(fan_in) gaussians give near-uniform attention, which is an
#: artifact of untrained weights, not of the attention mechanism.
QK_SHARPEN = 2.0


def init_weights(seed: int = 7, cfg: TinyLMConfig = CFG) -> Dict[str, jnp.ndarray]:
    """Deterministic synthetic weights (scaled gaussian; norms init to 1)."""
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, shape in weight_specs(cfg):
        key, sub = jax.random.split(key)
        if name.startswith("ln"):
            out[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            out[name] = (
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(jnp.float32(fan_in))
            )
            if name in ("wq", "wk"):
                out[name] = out[name] * QK_SHARPEN
    return out


WEIGHT_NAMES = [n for n, _ in weight_specs()]


def _rmsnorm(x, w, eps=CFG.eps):
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def _rope(x, pos, theta=CFG.rope_theta):
    """Rotary embedding. x [..., n_heads, d_head], pos [...] broadcastable."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = pos[..., None].astype(jnp.float32) * freqs  # [..., half]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over head axis
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _layer(weights, layer):
    """Slice the stacked per-layer weights at a (traced) layer index."""
    pick = lambda w: jax.lax.dynamic_index_in_dim(w, layer, 0, keepdims=False)
    return {k: pick(weights[k]) for k in ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2")}


# --------------------------------------------------------------------------
# Decode-step entry points (one PJRT call each, per layer)
# --------------------------------------------------------------------------

def qkv_step(ln1, wq, wk, wv, hidden, pos, layer, cfg: TinyLMConfig = CFG):
    """hidden [B,D], pos [B] i32, layer scalar i32 ->
    q [B,KVH,G,dh] (grouped for GQA), k [B,KVH,dh], v [B,KVH,dh].
    Keys are returned post-RoPE: the wave index clusters post-RoPE keys."""
    w_ln = jax.lax.dynamic_index_in_dim(ln1, layer, 0, keepdims=False)
    w_q = jax.lax.dynamic_index_in_dim(wq, layer, 0, keepdims=False)
    w_k = jax.lax.dynamic_index_in_dim(wk, layer, 0, keepdims=False)
    w_v = jax.lax.dynamic_index_in_dim(wv, layer, 0, keepdims=False)
    b = hidden.shape[0]
    x = _rmsnorm(hidden, w_ln)
    q = (x @ w_q).reshape(b, cfg.q_heads, cfg.d_head)
    k = (x @ w_k).reshape(b, cfg.kv_heads, cfg.d_head)
    v = (x @ w_v).reshape(b, cfg.kv_heads, cfg.d_head)
    q = _rope(q, pos)
    k = _rope(k, pos)
    q = q.reshape(b, cfg.kv_heads, cfg.group, cfg.d_head)
    return q, k, v


def qkv_step_l(ln1_l, wq_l, wk_l, wv_l, hidden, pos, cfg: TinyLMConfig = CFG):
    """Per-layer-weight variant of `qkv_step`: the caller passes the
    already-sliced layer weights, so the executable's parameters are 4x
    smaller (the L3 hot path pays a host->device copy per parameter per
    call — see EXPERIMENTS.md SPerf)."""
    b = hidden.shape[0]
    x = _rmsnorm(hidden, ln1_l)
    q = (x @ wq_l).reshape(b, cfg.q_heads, cfg.d_head)
    k = (x @ wk_l).reshape(b, cfg.kv_heads, cfg.d_head)
    v = (x @ wv_l).reshape(b, cfg.kv_heads, cfg.d_head)
    q = _rope(q, pos)
    k = _rope(k, pos)
    q = q.reshape(b, cfg.kv_heads, cfg.group, cfg.d_head)
    return q, k, v


def mlp_step_l(wo_l, ln2_l, w1_l, w2_l, hidden, ctx):
    """Per-layer-weight variant of `mlp_step` (see `qkv_step_l`)."""
    h = hidden + ctx @ wo_l
    x = _rmsnorm(h, ln2_l)
    return h + jax.nn.silu(x @ w1_l) @ w2_l


def attn_full_step(q, kc, vc, length, cfg: TinyLMConfig = CFG):
    """Full-attention decode (baseline): q [B,KVH,G,dh], kc/vc [B,KVH,T,dh],
    length [B] i32 (valid prefix per request) -> ctx [B, H*dh]."""
    b, kvh, t = kc.shape[0], kc.shape[1], kc.shape[2]
    mask = (jnp.arange(t)[None, None, :] < length[:, None, None]).astype(jnp.float32)
    mask = jnp.broadcast_to(mask, (b, kvh, t))
    ctx = ref.ref_full_attention(q, kc, vc, mask)  # [B,KVH,G,dh]
    return ctx.reshape(b, cfg.q_heads * cfg.d_head)


def attn_wave_step(q, kx, vx, kmask, cent, vsum, csize, emask, cfg: TinyLMConfig = CFG):
    """Tripartite attention decode through the L1 Pallas kernel."""
    b = q.shape[0]
    ctx = wave_attention(q, kx, vx, kmask, cent, vsum, csize, emask)
    return ctx.reshape(b, cfg.q_heads * cfg.d_head)


def mlp_step(wo, ln2, w1, w2, hidden, ctx, layer):
    """Output projection + residual + FFN + residual."""
    w_o = jax.lax.dynamic_index_in_dim(wo, layer, 0, keepdims=False)
    w_ln = jax.lax.dynamic_index_in_dim(ln2, layer, 0, keepdims=False)
    w_1 = jax.lax.dynamic_index_in_dim(w1, layer, 0, keepdims=False)
    w_2 = jax.lax.dynamic_index_in_dim(w2, layer, 0, keepdims=False)
    h = hidden + ctx @ w_o
    x = _rmsnorm(h, w_ln)
    return h + jax.nn.silu(x @ w_1) @ w_2


def logits_step(lnf, unemb, hidden):
    return _rmsnorm(hidden, lnf) @ unemb


def embed_step(tok_emb, tokens):
    """tokens [B] i32 -> hidden [B, D]."""
    return jnp.take(tok_emb, tokens, axis=0)


# --------------------------------------------------------------------------
# Prefill (whole prompt, chunked causal attention inside one executable)
# --------------------------------------------------------------------------

def prefill(weights, tokens, chunk: int = 512, cfg: TinyLMConfig = CFG):
    """tokens [B, T] i32 -> (K [L,B,KVH,T,dh], V [...], logits_last [B,V]).

    Causal attention is computed per query chunk to bound live memory to
    O(chunk * T) — the L2 analogue of the paper's FlashAttention prefill.
    Keys in the returned cache are post-RoPE.
    """
    b, t = tokens.shape
    assert t % chunk == 0, (t, chunk)
    h = embed_step(weights["tok_emb"], tokens.reshape(-1)).reshape(b, t, cfg.d_model)
    pos = jnp.arange(t, dtype=jnp.int32)

    k_cache = []
    v_cache = []
    for layer in range(cfg.n_layers):
        lw = {k: weights[k][layer] for k in ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2")}
        x = _rmsnorm(h, lw["ln1"])
        q = (x @ lw["wq"]).reshape(b, t, cfg.q_heads, cfg.d_head)
        k = (x @ lw["wk"]).reshape(b, t, cfg.kv_heads, cfg.d_head)
        v = (x @ lw["wv"]).reshape(b, t, cfg.kv_heads, cfg.d_head)
        q = _rope(q, pos[None, :])
        k = _rope(k, pos[None, :])
        # -> [B, KVH, T, dh]
        kt = jnp.transpose(k, (0, 2, 1, 3))
        vt = jnp.transpose(v, (0, 2, 1, 3))
        qg = jnp.transpose(q, (0, 2, 1, 3)).reshape(b, cfg.kv_heads, cfg.group, t, cfg.d_head)

        def chunk_attn(start):
            qc = jax.lax.dynamic_slice_in_dim(qg, start, chunk, axis=3)
            s = jnp.einsum("bhgqd,bhtd->bhgqt", qc, kt) / jnp.sqrt(jnp.float32(cfg.d_head))
            qpos = start + jnp.arange(chunk)
            causal = qpos[:, None] >= jnp.arange(t)[None, :]
            s = jnp.where(causal[None, None, None], s, ref.NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("bhgqt,bhtd->bhgqd", p, vt)

        starts = jnp.arange(0, t, chunk, dtype=jnp.int32)
        ctx = jax.lax.map(chunk_attn, starts)  # [n_chunks, B,KVH,G,chunk,dh]
        ctx = jnp.transpose(ctx, (1, 2, 3, 0, 4, 5)).reshape(
            b, cfg.kv_heads, cfg.group, t, cfg.d_head
        )
        ctx = jnp.transpose(ctx, (0, 3, 1, 2, 4)).reshape(b, t, cfg.q_dim)
        h = h + ctx @ lw["wo"]
        x2 = _rmsnorm(h, lw["ln2"])
        h = h + jax.nn.silu(x2 @ lw["w1"]) @ lw["w2"]
        k_cache.append(kt)
        v_cache.append(vt)

    logits_last = logits_step(weights["lnf"], weights["unemb"], h[:, -1, :])
    return jnp.stack(k_cache), jnp.stack(v_cache), logits_last


# --------------------------------------------------------------------------
# Reference decode (used by tests to validate the factored step functions)
# --------------------------------------------------------------------------

def decode_step_full(weights, token, pos, k_cache, v_cache, length, cfg: TinyLMConfig = CFG):
    """One full-attention decode step composed from the factored entry
    points, plus the new per-layer k/v. Used as the oracle for the
    prefill/decode-consistency test and by aot smoke checks.

    token [B] i32; pos [B] i32; k_cache/v_cache [L,B,KVH,T,dh]; length [B].
    Returns (logits [B,V], new_k [L,B,KVH,dh], new_v [L,B,KVH,dh]).
    """
    hidden = embed_step(weights["tok_emb"], token)
    new_ks, new_vs = [], []
    for layer in range(cfg.n_layers):
        q, k, v = qkv_step(
            weights["ln1"], weights["wq"], weights["wk"], weights["wv"],
            hidden, pos, layer,
        )
        # decode attends over the cache plus the current token's own k/v,
        # written in place at index `length` (mirrors the Rust cache layout)
        ins = lambda cache, kk, ln: jax.lax.dynamic_update_slice_in_dim(
            cache, kk[:, None, :], ln, axis=1
        )
        kc = jax.vmap(ins)(k_cache[layer], k, length)
        vc = jax.vmap(ins)(v_cache[layer], v, length)
        ctx = attn_full_step(q, kc, vc, length + 1)
        hidden = mlp_step(
            weights["wo"], weights["ln2"], weights["w1"], weights["w2"],
            hidden, ctx, layer,
        )
        new_ks.append(k)
        new_vs.append(v)
    logits = logits_step(weights["lnf"], weights["unemb"], hidden)
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)
