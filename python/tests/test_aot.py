"""AOT manifest + artifact sanity (does not require artifacts to be built:
only validates the declared signatures and, when present, the files)."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_entry_points_unique_names():
    eps = aot.entry_points()
    names = [e[0] for e in eps]
    assert len(names) == len(set(names))
    # every batch bucket has the full per-layer decode set
    for b in aot.BATCH_BUCKETS:
        for stem in ("embed", "qkv", "mlp", "logits", "attn_wave"):
            assert f"{stem}_b{b}" in names
    for t in aot.PREFILL_T:
        assert f"prefill_b1_t{t}" in names


def test_param_names_match_spec_counts():
    for name, fn, arg_specs, param_names, outputs in aot.entry_points():
        flat = aot._flat_specs(arg_specs)
        assert len(flat) == len(param_names), name
        assert len(outputs) >= 1, name


def test_wave_shapes_block_aligned():
    assert aot.WAVE_NE % 128 == 0
    assert aot.WAVE_M % 128 == 0
    assert aot.WAVE_NE > aot.STEADY_SINK + aot.STEADY_LOCAL


def test_weights_bin_layout(tmp_path):
    manifest = aot.write_weights(str(tmp_path), seed=7)
    size = os.path.getsize(tmp_path / "weights.bin")
    total = sum(m["elements"] for m in manifest)
    assert size == total * 4
    # offsets are contiguous and ordered per weight_specs
    off = 0
    for m, (name, shape) in zip(manifest, M.weight_specs()):
        assert m["name"] == name
        assert m["offset"] == off
        assert m["elements"] == int(np.prod(shape))
        off += m["elements"] * 4


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_artifacts_complete():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["model"]["name"] == "tinylm"
    for exe in manifest["executables"]:
        path = os.path.join(ART, exe["file"])
        assert os.path.exists(path), exe["name"]
        head = open(path).read(200)
        assert "HloModule" in head, exe["name"]
    wpath = os.path.join(ART, manifest["model"]["weights_file"])
    total = sum(w["elements"] for w in manifest["weights"])
    assert os.path.getsize(wpath) == total * 4


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_zone_defaults_match_paper():
    with open(os.path.join(ART, "manifest.json")) as f:
        z = json.load(f)["zones"]
    assert z["steady_sink"] == 4 and z["steady_local"] == 64
    assert z["tokens_per_cluster"] == 16
    assert abs(z["retrieval_frac"] - 0.018) < 1e-9
    assert abs(z["estimation_frac"] - 0.232) < 1e-9
    assert z["build_segment"] == 8192 and z["update_segment"] == 1024
