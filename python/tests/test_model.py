"""L2 model tests: shapes, prefill/decode consistency, wave-vs-full fidelity."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model as M
from compile.kernels.kmeans import segmented_kmeans


@pytest.fixture(scope="module")
def weights():
    return M.init_weights()


def test_weight_specs_deterministic(weights):
    w2 = M.init_weights()
    for name in M.WEIGHT_NAMES:
        np.testing.assert_array_equal(np.asarray(weights[name]), np.asarray(w2[name]))


def test_prefill_shapes(weights):
    cfg = M.CFG
    K, V, logits = M.prefill(weights, jnp.zeros((2, 64), jnp.int32), chunk=32)
    assert K.shape == (cfg.n_layers, 2, cfg.kv_heads, 64, cfg.d_head)
    assert V.shape == K.shape
    assert logits.shape == (2, cfg.vocab)


def test_prefill_chunk_invariance(weights):
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 256, (1, 96)), jnp.int32)
    K1, V1, l1 = M.prefill(weights, toks, chunk=32)
    K2, V2, l2 = M.prefill(weights, toks, chunk=96)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(K1), np.asarray(K2), rtol=1e-4, atol=1e-5)


def test_decode_matches_prefill(weights):
    """Factored per-layer decode over a padded cache == one-shot prefill."""
    rng = np.random.default_rng(1)
    B, T = 2, 64
    toks = rng.integers(0, 256, (B, T + 1)).astype(np.int32)
    K, V, _ = M.prefill(weights, jnp.asarray(toks[:, :T]), chunk=32)
    pad = 32
    Kp = jnp.pad(K, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    Vp = jnp.pad(V, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    length = jnp.full((B,), T, jnp.int32)
    logits, nk, nv = M.decode_step_full(
        weights, jnp.asarray(toks[:, T]), length, Kp, Vp, length)
    K2, V2, logits2 = M.prefill(weights, jnp.asarray(toks), chunk=13)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(nk), np.asarray(K2[:, :, :, T, :]), rtol=1e-3, atol=1e-4)


def test_rope_position_dependence():
    x = jnp.ones((1, 2, M.CFG.d_head))
    a = M._rope(x, jnp.asarray([0], jnp.int32))
    b = M._rope(x, jnp.asarray([5], jnp.int32))
    assert not np.allclose(np.asarray(a), np.asarray(b))
    # norm-preserving rotation
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(a)), np.linalg.norm(np.asarray(b)), rtol=1e-5)


def test_wave_decode_close_to_full(weights):
    """End-to-end L2 composition check: wave attention with a real wave
    index built on TinyLM's own KV cache (a) stays close to full-attention
    decode and (b) the estimation zone strictly improves fidelity.

    NOTE: untrained-transformer KV geometry lacks the heavy-hitter/cluster
    correlation of trained LLMs (DESIGN.md §1), so thresholds here check
    composition and the estimation mechanism, not the paper's end-task
    accuracy — that is reproduced by the Rust fig10/fig11 benches on
    constructed KV geometry.
    """
    cfg = M.CFG
    rng = np.random.default_rng(2)
    B, T = 1, 1024
    toks = rng.integers(0, 256, (B, T)).astype(np.int32)
    K, V, _ = M.prefill(weights, jnp.asarray(toks), chunk=128)

    # decode one step with full attention (oracle)
    length = jnp.full((B,), T, jnp.int32)
    pad = 64
    Kp = jnp.pad(K, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    Vp = jnp.pad(V, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    tok = jnp.asarray(toks[:, -1])
    logits_full, _, _ = M.decode_step_full(weights, tok, length, Kp, Vp, length)

    # wave decode: build index per layer (single segment), steady=4+64,
    # retrieval = top 25% clusters, estimation = rest
    n_clusters = T // 16
    sink, local = 4, 64

    def wave_logits(use_estimation):
        return _wave_decode(weights, tok, length, K, V, n_clusters, sink, local,
                            use_estimation)

    logits_wave = wave_logits(True)
    logits_noest = wave_logits(False)

    def cos(a, b):
        a, b = np.asarray(a[0]), np.asarray(b[0])
        return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))

    # Untrained-transformer logits compound per-layer drift; 0.5 is far
    # above chance (~0.0) and checks the stack composes, while the second
    # assertion checks the estimation mechanism itself.
    c_est = cos(logits_full, logits_wave)
    c_noest = cos(logits_full, logits_noest)
    assert c_est > 0.5, f"wave decode diverged from full attention: cos={c_est}"
    # On untrained geometry estimation is roughly neutral (its win shows on
    # clustered geometry — rust fig19 bench); assert it does not hurt.
    assert c_est >= c_noest - 0.05, (
        f"estimation zone hurt fidelity: with={c_est} without={c_noest}")


def _wave_decode(weights, tok, length, K, V, n_clusters, sink, local, use_estimation):
    cfg = M.CFG
    T = K.shape[3]
    hidden = M.embed_step(weights["tok_emb"], tok)
    for layer in range(cfg.n_layers):
        q, k, v = M.qkv_step(
            weights["ln1"], weights["wq"], weights["wk"], weights["wv"],
            hidden, length, layer)
        keys_l, vals_l = K[layer, 0], V[layer, 0]  # [KVH, T, dh]
        mid_k, mid_v = keys_l[:, sink:T - local], vals_l[:, sink:T - local]
        cent, vsum, csize, asg_all = segmented_kmeans(
            mid_k, mid_v, n_clusters=n_clusters, n_iters=6)
        # score clusters by max over the query-head group
        scores = jnp.max(jnp.einsum("hgd,hcd->hgc", q[0], cent), axis=1)
        r = max(n_clusters // 4, 1)
        top = jnp.argsort(-scores, axis=-1)[:, :r]  # [KVH, r]

        ne_cap = 68 + 512
        kx = np.zeros((1, cfg.kv_heads, ne_cap, cfg.d_head), np.float32)
        vx = np.zeros_like(kx)
        kmask = np.zeros((1, cfg.kv_heads, ne_cap), np.float32)
        emask = np.ones((1, cfg.kv_heads, n_clusters), np.float32)
        # steady zone: sinks + local window + current token's own kv
        for h in range(cfg.kv_heads):
            steady_k = np.concatenate(
                [np.asarray(keys_l[h, :sink]), np.asarray(keys_l[h, T - local:]),
                 np.asarray(k[0, h])[None]], 0)
            steady_v = np.concatenate(
                [np.asarray(vals_l[h, :sink]), np.asarray(vals_l[h, T - local:]),
                 np.asarray(v[0, h])[None]], 0)
            n = len(steady_k)
            kx[0, h, :n] = steady_k
            vx[0, h, :n] = steady_v
            kmask[0, h, :n] = 1
            # retrieval zone: all tokens of top clusters (exact)
            asg = np.asarray(asg_all[h])
            sel = np.isin(asg, np.asarray(top[h]))
            sel_k, sel_v = np.asarray(mid_k[h])[sel], np.asarray(mid_v[h])[sel]
            cap = min(len(sel_k), ne_cap - n)
            kx[0, h, n:n + cap] = sel_k[:cap]
            vx[0, h, n:n + cap] = sel_v[:cap]
            kmask[0, h, n:n + cap] = 1
            emask[0, h, np.asarray(top[h])] = 0  # retrieved -> not estimated

        if not use_estimation:
            emask[:] = 0.0

        ctx = M.attn_wave_step(
            q, jnp.asarray(kx), jnp.asarray(vx), jnp.asarray(kmask),
            cent[None], vsum[None], csize[None], jnp.asarray(emask))
        hidden = M.mlp_step(
            weights["wo"], weights["ln2"], weights["w1"], weights["w2"],
            hidden, ctx, layer)
    return M.logits_step(weights["lnf"], weights["unemb"], hidden)
