"""L1 kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes/masks through the Pallas tripartite-attention
kernel (interpret=True) and asserts allclose against ref.py.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.wave_attention import wave_attention
from compile.kernels import ref

RTOL, ATOL = 2e-5, 2e-6


def _inputs(rng, b, kvh, g, d, ne, m, kmask_p=0.8, emask_p=0.7, scale=1.0):
    q = rng.standard_normal((b, kvh, g, d)).astype(np.float32) * scale
    kx = rng.standard_normal((b, kvh, ne, d)).astype(np.float32)
    vx = rng.standard_normal((b, kvh, ne, d)).astype(np.float32)
    kmask = (rng.random((b, kvh, ne)) < kmask_p).astype(np.float32)
    # guarantee at least one valid exact token per head (steady zone invariant)
    kmask[:, :, 0] = 1.0
    cent = rng.standard_normal((b, kvh, m, d)).astype(np.float32)
    vsum = rng.standard_normal((b, kvh, m, d)).astype(np.float32) * 4.0
    csize = rng.integers(1, 32, (b, kvh, m)).astype(np.float32)
    emask = (rng.random((b, kvh, m)) < emask_p).astype(np.float32)
    return q, kx, vx, kmask, cent, vsum, csize, emask


def _check(args, block_k=128):
    got = np.asarray(wave_attention(*args, block_k=block_k))
    want = np.asarray(ref.ref_wave_attention(*args))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_basic_shapes():
    rng = np.random.default_rng(0)
    _check(_inputs(rng, 2, 2, 4, 32, 256, 64))


def test_single_batch_single_head():
    rng = np.random.default_rng(1)
    _check(_inputs(rng, 1, 1, 1, 16, 64, 32), block_k=32)


def test_non_multiple_block_padding():
    """Ne/M not multiples of block_k exercise the padding path."""
    rng = np.random.default_rng(2)
    _check(_inputs(rng, 1, 2, 4, 32, 100, 37), block_k=32)


def test_no_estimation_zone_matches_masked_full():
    """emask all-zero => pure exact attention over valid tokens."""
    rng = np.random.default_rng(3)
    q, kx, vx, kmask, cent, vsum, csize, emask = _inputs(rng, 1, 2, 4, 32, 128, 32)
    emask = np.zeros_like(emask)
    got = np.asarray(wave_attention(q, kx, vx, kmask, cent, vsum, csize, emask))
    want = np.asarray(ref.ref_full_attention(q, kx, vx, kmask))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_all_exact_masked_pure_estimation():
    """kmask all-zero => output comes only from the estimation zone."""
    rng = np.random.default_rng(4)
    q, kx, vx, kmask, cent, vsum, csize, emask = _inputs(rng, 1, 1, 2, 32, 64, 32)
    kmask = np.zeros_like(kmask)
    emask = np.ones_like(emask)
    got = np.asarray(wave_attention(q, kx, vx, kmask, cent, vsum, csize, emask))
    want = np.asarray(ref.ref_wave_attention(q, kx, vx, kmask, cent, vsum, csize, emask))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    assert np.all(np.isfinite(got))


def test_numerical_stability_large_scores():
    """Large logits must not overflow thanks to the online max."""
    rng = np.random.default_rng(5)
    args = _inputs(rng, 1, 1, 2, 32, 64, 32, scale=40.0)
    got = np.asarray(wave_attention(*args, block_k=32))
    assert np.all(np.isfinite(got))
    _check(args, block_k=32)


def test_singleton_clusters_equal_exact():
    """If every cluster has size 1, centroid==key and vsum==value, the
    estimation zone must reproduce exact attention over those tokens."""
    rng = np.random.default_rng(6)
    b, kvh, g, d, ne = 1, 2, 4, 32, 64
    q = rng.standard_normal((b, kvh, g, d)).astype(np.float32)
    keys = rng.standard_normal((b, kvh, ne, d)).astype(np.float32)
    vals = rng.standard_normal((b, kvh, ne, d)).astype(np.float32)
    ones = np.ones((b, kvh, ne), np.float32)
    # exact path
    exact = np.asarray(ref.ref_full_attention(q, keys, vals, ones))
    # estimation-only path with singleton clusters
    zeros_mask = np.zeros((b, kvh, ne), np.float32)
    got = np.asarray(
        wave_attention(q, keys, vals, zeros_mask, keys, vals, ones, ones, block_k=32)
    )
    np.testing.assert_allclose(got, exact, rtol=RTOL, atol=ATOL)


def test_jensen_bound_denominator():
    """Estimated softmax denominator lower-bounds the true one (Eq. 3):
    s_i * exp(q.C_i) <= sum_j exp(q.K_j) when C_i is the member mean."""
    rng = np.random.default_rng(7)
    d, n = 32, 128
    keys = rng.standard_normal((n, d)).astype(np.float32)
    q = rng.standard_normal((d,)).astype(np.float32)
    assign = rng.integers(0, 8, n)
    scale = 1.0 / np.sqrt(d)
    for c in range(8):
        members = keys[assign == c]
        if len(members) == 0:
            continue
        cent = members.mean(axis=0)
        lhs = len(members) * np.exp(np.float64(q @ cent) * scale)
        rhs = np.exp((members @ q).astype(np.float64) * scale).sum()
        assert lhs <= rhs * (1 + 1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    kvh=st.integers(1, 3),
    g=st.integers(1, 4),
    d=st.sampled_from([8, 16, 32]),
    ne=st.integers(8, 200),
    m=st.integers(4, 80),
    block_k=st.sampled_from([16, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(b, kvh, g, d, ne, m, block_k, seed):
    rng = np.random.default_rng(seed)
    _check(_inputs(rng, b, kvh, g, d, ne, m), block_k=block_k)


@settings(max_examples=10, deadline=None)
@given(
    kmask_p=st.floats(0.05, 1.0),
    emask_p=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_mask_densities(kmask_p, emask_p, seed):
    rng = np.random.default_rng(seed)
    _check(_inputs(rng, 1, 2, 4, 32, 96, 40, kmask_p, emask_p), block_k=32)
