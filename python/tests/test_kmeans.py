"""Segmented spherical k-means: Pallas assign kernel vs oracle + invariants."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.kmeans import (
    kmeans_assign,
    segmented_kmeans,
    _center_normalize,
)
from compile.kernels import ref


def test_assign_matches_ref():
    rng = np.random.default_rng(0)
    keys = rng.standard_normal((2, 300, 32)).astype(np.float32)
    cent = rng.standard_normal((2, 24, 32)).astype(np.float32)
    got = np.asarray(kmeans_assign(jnp.asarray(keys), jnp.asarray(cent), block_s=64))
    want = np.asarray(ref.ref_kmeans_assign(keys, cent))
    assert (got == want).all()


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(1, 3),
    s=st.integers(10, 400),
    c=st.integers(2, 48),
    d=st.sampled_from([8, 16, 32]),
    block_s=st.sampled_from([32, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_assign_hypothesis(h, s, c, d, block_s, seed):
    rng = np.random.default_rng(seed)
    keys = rng.standard_normal((h, s, d)).astype(np.float32)
    cent = rng.standard_normal((h, c, d)).astype(np.float32)
    got = np.asarray(kmeans_assign(jnp.asarray(keys), jnp.asarray(cent), block_s=block_s))
    want = np.asarray(ref.ref_kmeans_assign(keys, cent))
    # argmax ties can legitimately differ; verify by similarity equality
    sims = np.einsum("hsd,hcd->hsc", keys, cent)
    np.testing.assert_allclose(
        np.take_along_axis(sims, got[..., None], -1),
        np.take_along_axis(sims, want[..., None], -1),
        rtol=1e-5, atol=1e-6,
    )


def test_counts_and_sums_consistent():
    rng = np.random.default_rng(1)
    keys = rng.standard_normal((2, 512, 32)).astype(np.float32)
    vals = rng.standard_normal((2, 512, 32)).astype(np.float32)
    mc, vs, cnt, asg = map(np.asarray, segmented_kmeans(
        jnp.asarray(keys), jnp.asarray(vals), n_clusters=32, n_iters=4))
    assert cnt.sum() == 2 * 512
    for h in range(2):
        for c in range(32):
            members = asg[h] == c
            assert members.sum() == cnt[h, c]
            if members.sum() > 0:
                np.testing.assert_allclose(
                    vs[h, c], vals[h][members].sum(axis=0), rtol=1e-4, atol=1e-4)
                np.testing.assert_allclose(
                    mc[h, c], keys[h][members].mean(axis=0), rtol=1e-4, atol=1e-4)
            else:
                assert np.allclose(vs[h, c], 0) and np.allclose(mc[h, c], 0)


def test_meta_centroid_is_raw_mean_for_jensen():
    """The meta centroid must be the raw mean (Jensen bound, Eq. 3) even
    though clustering geometry is centered+normalized."""
    rng = np.random.default_rng(2)
    keys = rng.standard_normal((1, 256, 16)).astype(np.float32) + 3.0  # offset mean
    vals = rng.standard_normal((1, 256, 16)).astype(np.float32)
    mc, _, cnt, asg = map(np.asarray, segmented_kmeans(
        jnp.asarray(keys), jnp.asarray(vals), n_clusters=16, n_iters=4))
    q = rng.standard_normal((16,)).astype(np.float32)
    scale = 1 / np.sqrt(16)
    for c in range(16):
        members = keys[0][asg[0] == c]
        if len(members) == 0:
            continue
        lhs = len(members) * np.exp(np.float64(q @ mc[0, c]) * scale)
        rhs = np.exp((members @ q).astype(np.float64) * scale).sum()
        assert lhs <= rhs * (1 + 1e-5)


def test_clustering_recovers_planted_clusters():
    """Well-separated planted clusters should be recovered (high purity)."""
    rng = np.random.default_rng(3)
    d, per, k = 32, 64, 8
    centers = rng.standard_normal((k, d)).astype(np.float32) * 8
    keys = np.concatenate(
        [centers[i] + 0.1 * rng.standard_normal((per, d)) for i in range(k)]
    ).astype(np.float32)[None]
    vals = np.zeros_like(keys)
    _, _, _, asg = segmented_kmeans(
        jnp.asarray(keys), jnp.asarray(vals), n_clusters=k, n_iters=10)
    asg = np.asarray(asg)[0]
    purity = 0
    for i in range(k):
        labels, counts = np.unique(asg[i * per:(i + 1) * per], return_counts=True)
        purity += counts.max()
    assert purity / (k * per) > 0.9


def test_center_normalize_unit_norm():
    rng = np.random.default_rng(4)
    keys = rng.standard_normal((2, 100, 16)).astype(np.float32) * 5 + 2
    kcn = np.asarray(_center_normalize(jnp.asarray(keys)))
    np.testing.assert_allclose(np.linalg.norm(kcn, axis=-1), 1.0, atol=1e-5)
    np.testing.assert_allclose(kcn.mean(axis=1) @ np.ones(16), 0.0, atol=1.0)
